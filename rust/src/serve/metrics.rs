//! Aggregate serving metrics: the per-run report `wdb serve-bench` and the
//! serving bench harness table-ify.

use super::session::SessionState;
use crate::trace::Histogram;

/// Aggregate results of one serving run (a batch of sessions driven to
/// completion), in virtual ns of the shared device clock.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub sessions: usize,
    pub total_tokens: usize,
    /// Virtual wall time of the whole run (first admit to last retire).
    pub wall_virtual_ns: u64,
    /// total_tokens / wall — the serving-side headline metric.
    pub agg_tok_per_s: f64,
    pub mean_ttft_ms: f64,
    pub max_ttft_ms: f64,
    /// Mean per-session prompt-ingestion latency (admission to the encode
    /// consuming the final prompt token) — the TTFT component chunked
    /// prefill collapses (table S2's `(prefill ms)` row).
    pub mean_prefill_ms: f64,
    /// Mean per-session first-decode latency (end of prompt ingestion to
    /// the first generated token's selection — the readback/sync side of
    /// TTFT; table S2's `(first decode ms)` row).
    pub mean_first_decode_ms: f64,
    /// Mean of per-session generation throughput (tokens / generation ns).
    pub mean_session_tok_per_s: f64,
    /// Total dispatches across sessions.
    pub dispatches: u64,
    /// Total decode steps across sessions (prefill + generation). Chunked
    /// prefill counts one step per prompt TOKEN (a C-token chunk is C
    /// steps), so per-step rates stay comparable across ingestion modes.
    pub steps: u64,
    /// Prompt tokens ingested across sessions.
    pub prefill_steps: u64,
    /// Dispatches attributed to prompt ingestion across sessions.
    pub prefill_dispatches: u64,
    /// Dispatches per decode step (uniform across sessions of one config).
    pub dispatches_per_step: u64,
    /// Aggregate per-phase dispatch CPU cost (`DISPATCH_PHASES` order).
    pub phase_virtual_ns: [u64; 8],
    pub framework_virtual_ns: u64,
    pub sync_virtual_ns: u64,
    pub kernel_virtual_ns: u64,
    /// Per-session encode (planned: plan-replay) CPU cost, summed.
    pub encode_virtual_ns: u64,
    /// Host->device bytes uploaded across sessions (per-step inputs; in
    /// eager mode also activations + caches — the traffic resident caches
    /// remove).
    pub upload_bytes: u64,
    /// Device bytes of ONE session's resident KV-cache set (0 in eager
    /// mode: caches live host-side and ride `upload_bytes` instead).
    pub resident_bytes: u64,
    pub ttft_ms: Vec<f64>,
    /// Scheduler rounds completed by the run (the denominator of
    /// [`ServeReport::dispatches_per_round`] — table S1's batching
    /// evidence column).
    pub rounds: u64,
    /// Batched slot width the run served with (0 = interleaved rounds;
    /// >= 2 = rounds with that many active sessions replayed the batched
    /// plan, one dispatch per layer op per chunk).
    pub batch_width: usize,
    /// Chunked-prefill size the run served with (0 = token-by-token
    /// prompt ingestion; >= 2 = prompts replayed the seq-dim prefill plan
    /// in chunks of that many tokens).
    pub prefill_chunk: usize,
    /// True when EVERY round replayed the unified `[W*C, H]` seq-x-batch
    /// plan (continuous batching: prefill chunks and decode steps share
    /// one dispatch per layer op per chunk of `batch_width` slots).
    /// `batch_width`/`prefill_chunk` then report the unified plan's W/C.
    pub unified: bool,
    /// Speculative draft depth the run served with (0 = off; >= 1 = up to
    /// that many n-gram-drafted tokens verified per session per unified
    /// round). [`ServeReport::tokens_per_round`] is the headline it moves.
    pub speculate: usize,
    /// Speculative decode: draft tokens submitted to verify rounds.
    pub drafted: u64,
    /// Speculative decode: draft tokens accepted (greedy-matched).
    pub accepted: u64,
    /// True when the run replayed a compiled plan instead of eager-
    /// interpreting the graph (the [`ServeReport::exec_mode`] header
    /// derives from this).
    pub planned: bool,
    /// One-time plan compile + materialize cost (virtual ns; 0 in eager
    /// mode). Attributed at engine level — it precedes every session.
    pub plan_build_virtual_ns: u64,
    /// Real host ns of the plan build.
    pub plan_build_real_ns: u64,
    /// Peak outstanding bytes in the shared activation pool.
    pub pool_high_water_bytes: u64,
    /// Buffers the pool created over the run (reuse keeps this flat).
    pub pool_buffers_created: u64,
    /// Idle buffers the pool destroyed to admit an over-cap acquire
    /// (evict-LRU-then-retry; 0 = the cap was never under pressure).
    pub pool_evictions: u64,
    /// Faults the installed injector fired over the run (0 = none
    /// installed or none triggered).
    pub faults_injected: u64,
    /// Transient-fault recoveries engine-wide: quarantined chunks,
    /// re-issued readbacks/spills, retried admissions.
    pub retries: u64,
    /// Retired sessions that completed in full despite >= 1 transient
    /// fault — byte-identical streams to the uninjected twin.
    pub recovered_sessions: u64,
    /// Sessions abandoned after exhausting their retry budget (their
    /// committed-token prefix still reports).
    pub failed_sessions: u64,
    /// Seed of the installed fault plan (`None` = no injection) — makes
    /// every faulted run reproducible from its report header.
    pub fault_seed: Option<u64>,
    /// Paged KV block size in tokens (0 = contiguous per-session cache
    /// sets — the pre-paging layout).
    pub kv_block: usize,
    /// Device bytes of ONE block group (all 2xlayers plane slices; 0 in
    /// contiguous mode).
    pub kv_group_bytes: u64,
    /// Peak simultaneously-granted block groups in the shared pool.
    pub kv_pool_high_water_groups: u64,
    /// Host->device block hydrations the pager performed.
    pub kv_page_ins: u64,
    /// Device->host block spills (LRU page-outs + quarantine evictions).
    pub kv_page_outs: u64,
    /// Summed per-session high-water block-table lengths.
    pub kv_blocks_hw: u64,
    /// Summed per-session high-water spilled-block counts.
    pub kv_blocks_spilled_hw: u64,
    /// High-water mark of simultaneously KV-resident sessions — the
    /// density metric paged residency exists to raise at equal pool cap.
    pub resident_sessions_hw: u64,
    /// Per-session TTFT distribution (ns; log-bucketed, ±6.25%). Means
    /// stay the S1/S2 compat surface; the p50/p90/p99 accessors below
    /// read these.
    pub ttft_hist: Histogram,
    /// Per-session prompt-ingestion latency distribution (ns).
    pub prefill_hist: Histogram,
    /// Inter-token latency distribution (ns): every per-decode-step delta
    /// AFTER a session's first token, across sessions.
    pub itl_hist: Histogram,
    /// Scheduler-round duration distribution (ns), from the tracer's
    /// metrics registry (recorded regardless of sink).
    pub round_hist: Histogram,
    /// Synchronizing map-read wait distribution (ns), from the tracer.
    pub map_wait_hist: Histogram,
    /// Trace events emitted over the run (every sink counts; Null retains
    /// none of them).
    pub trace_events: u64,
    /// Trace events the ring sink overwrote (0 for Null/Chrome sinks).
    pub trace_dropped_events: u64,
}

impl ServeReport {
    pub fn from_sessions(sessions: &[SessionState], wall_virtual_ns: u64) -> Self {
        let n = sessions.len();
        let total_tokens: usize = sessions.iter().map(|s| s.tokens.len()).sum();
        let mut phase = [0u64; 8];
        let mut framework = 0u64;
        let mut sync = 0u64;
        let mut kernel = 0u64;
        let mut encode = 0u64;
        let mut upload_bytes = 0u64;
        let mut dispatches = 0u64;
        let mut steps = 0u64;
        let mut prefill_steps = 0u64;
        let mut prefill_dispatches = 0u64;
        let mut prefill_ms_sum = 0f64;
        let mut first_decode_ms_sum = 0f64;
        let mut drafted = 0u64;
        let mut accepted = 0u64;
        let mut kv_blocks_hw = 0u64;
        let mut kv_blocks_spilled_hw = 0u64;
        let mut ttft_ms = Vec::with_capacity(n);
        let mut tps_sum = 0f64;
        let mut ttft_hist = Histogram::new();
        let mut prefill_hist = Histogram::new();
        let mut itl_hist = Histogram::new();
        for s in sessions {
            for i in 0..8 {
                phase[i] += s.metrics.phase_virtual_ns[i];
            }
            framework += s.metrics.framework_virtual_ns;
            sync += s.metrics.sync_virtual_ns;
            kernel += s.metrics.kernel_virtual_ns;
            encode += s.metrics.encode_virtual_ns;
            upload_bytes += s.metrics.upload_bytes;
            dispatches += s.metrics.dispatches;
            steps += s.metrics.steps;
            prefill_steps += s.metrics.prefill_steps;
            prefill_dispatches += s.metrics.prefill_dispatches;
            drafted += s.metrics.drafted;
            accepted += s.metrics.accepted;
            kv_blocks_hw += s.metrics.kv_blocks_hw;
            kv_blocks_spilled_hw += s.metrics.kv_blocks_spilled_hw;
            prefill_ms_sum += s.metrics.prefill_ns() as f64 / 1e6;
            first_decode_ms_sum += s.metrics.first_decode_ns() as f64 / 1e6;
            ttft_ms.push(s.metrics.ttft_ns() as f64 / 1e6);
            ttft_hist.record(s.metrics.ttft_ns());
            prefill_hist.record(s.metrics.prefill_ns());
            // per_token_ns[0] is TTFT-from-admission; everything after is
            // an inter-token delta.
            for &d in s.metrics.per_token_ns.iter().skip(1) {
                itl_hist.record(d);
            }
            let gen_ns = s.metrics.generation_ns().max(1);
            tps_sum += s.tokens.len() as f64 / (gen_ns as f64 / 1e9);
        }
        let wall = wall_virtual_ns.max(1);
        ServeReport {
            sessions: n,
            total_tokens,
            wall_virtual_ns,
            agg_tok_per_s: total_tokens as f64 / (wall as f64 / 1e9),
            mean_ttft_ms: if n > 0 {
                ttft_ms.iter().sum::<f64>() / n as f64
            } else {
                0.0
            },
            max_ttft_ms: ttft_ms.iter().cloned().fold(0.0, f64::max),
            mean_prefill_ms: if n > 0 { prefill_ms_sum / n as f64 } else { 0.0 },
            mean_first_decode_ms: if n > 0 { first_decode_ms_sum / n as f64 } else { 0.0 },
            mean_session_tok_per_s: if n > 0 { tps_sum / n as f64 } else { 0.0 },
            dispatches,
            steps,
            prefill_steps,
            prefill_dispatches,
            dispatches_per_step: if steps > 0 { dispatches / steps } else { 0 },
            phase_virtual_ns: phase,
            framework_virtual_ns: framework,
            sync_virtual_ns: sync,
            kernel_virtual_ns: kernel,
            encode_virtual_ns: encode,
            upload_bytes,
            resident_bytes: 0,
            ttft_ms,
            rounds: 0,
            batch_width: 0,
            prefill_chunk: 0,
            unified: false,
            speculate: 0,
            drafted,
            accepted,
            planned: false,
            plan_build_virtual_ns: 0,
            plan_build_real_ns: 0,
            pool_high_water_bytes: 0,
            pool_buffers_created: 0,
            pool_evictions: 0,
            faults_injected: 0,
            retries: 0,
            recovered_sessions: 0,
            failed_sessions: 0,
            fault_seed: None,
            kv_block: 0,
            kv_group_bytes: 0,
            kv_pool_high_water_groups: 0,
            kv_page_ins: 0,
            kv_page_outs: 0,
            kv_blocks_hw,
            kv_blocks_spilled_hw,
            resident_sessions_hw: 0,
            ttft_hist,
            prefill_hist,
            itl_hist,
            round_hist: Histogram::new(),
            map_wait_hist: Histogram::new(),
            trace_events: 0,
            trace_dropped_events: 0,
        }
    }

    /// Total dispatch-phase CPU ns.
    pub fn phase_total_ns(&self) -> u64 {
        self.phase_virtual_ns.iter().sum()
    }

    /// Microseconds of `ns` per generated token.
    pub fn us_per_token(&self, ns: u64) -> f64 {
        ns as f64 / 1e3 / self.total_tokens.max(1) as f64
    }

    /// Host upload bytes per decode step (prefill + generation) — the
    /// quantity device-resident caches shrink to embedding + uniforms.
    pub fn upload_bytes_per_step(&self) -> f64 {
        self.upload_bytes as f64 / self.steps.max(1) as f64
    }

    /// Execution-mode header for tables and artifact names, derived from
    /// [`ServeReport::planned`] (single source of truth).
    pub fn exec_mode(&self) -> &'static str {
        if self.planned {
            "planned"
        } else {
            "eager"
        }
    }

    /// Self-describing mode label for report headers: exec mode plus the
    /// batched slot width and prefill chunk when those paths were active.
    /// A unified run subsumes both — every round replayed the one
    /// seq-x-batch plan — so it labels as `+unified(w=W,c=C)` instead.
    pub fn mode_label(&self) -> String {
        let mut label = self.exec_mode().to_string();
        if self.kv_block > 0 {
            // The KV layout qualifies the exec mode itself (every plan of
            // the run was built with block-table indirection).
            label.push_str(&format!("+paged(b={})", self.kv_block));
        }
        if self.unified && self.batch_width >= 2 && self.prefill_chunk >= 2 {
            label.push_str(&format!(
                "+unified(w={},c={})",
                self.batch_width, self.prefill_chunk
            ));
            if self.speculate >= 1 {
                label.push_str(&format!("+spec(k={})", self.speculate));
            }
            if let Some(seed) = self.fault_seed {
                label.push_str(&format!("+faults(seed={seed})"));
            }
            return label;
        }
        if self.batch_width >= 2 {
            label.push_str(&format!("+batched(w={})", self.batch_width));
        }
        if self.prefill_chunk >= 2 {
            label.push_str(&format!("+prefill(c={})", self.prefill_chunk));
        }
        if let Some(seed) = self.fault_seed {
            label.push_str(&format!("+faults(seed={seed})"));
        }
        label
    }

    /// Prefill dispatches per prompt token — the chunked-prefill
    /// headline (table S1's `prefill disp/tok` column): token-by-token
    /// ingestion pays the full per-step dispatch count per prompt token;
    /// a C-token chunk pays ~1/C of it.
    pub fn prefill_dispatches_per_prompt_token(&self) -> f64 {
        self.prefill_dispatches as f64 / self.prefill_steps.max(1) as f64
    }

    /// WebGPU dispatches per scheduler round — the batched-decode headline:
    /// interleaved rounds pay N x (dispatches/step); batched rounds pay
    /// ceil(N / width) x (dispatches/step).
    pub fn dispatches_per_round(&self) -> f64 {
        self.dispatches as f64 / self.rounds.max(1) as f64
    }

    /// Generated tokens per scheduler round — the speculative-decode
    /// headline: non-speculative greedy decode emits at most one token per
    /// session per round; accepted drafts push this past 1x (the
    /// per-generated-token share of the paper's per-round dispatch bill
    /// falls by the same factor).
    pub fn tokens_per_round(&self) -> f64 {
        self.total_tokens as f64 / self.rounds.max(1) as f64
    }

    /// Peak device KV bytes per ACTUAL stored token row — the internal-
    /// fragmentation headline. Contiguous sets pay `max_seq` rows per
    /// resident session regardless of occupancy; paged residency pays at
    /// most one ragged tail block per session. `steps` (prompt + generated
    /// tokens) is the run's stored-row count.
    pub fn kv_bytes_per_token(&self) -> f64 {
        let peak = if self.kv_block > 0 {
            self.kv_pool_high_water_groups * self.kv_group_bytes
        } else {
            self.resident_sessions_hw * self.resident_bytes
        };
        peak as f64 / self.steps.max(1) as f64
    }

    /// Fraction of drafted tokens the verify rounds accepted (0.0 when
    /// nothing was drafted).
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    // ------------------------- latency percentiles (histogram-backed) ----

    /// Median request-level TTFT in ms (0.0 with no sessions).
    pub fn ttft_p50_ms(&self) -> f64 {
        self.ttft_hist.percentile(0.50) as f64 / 1e6
    }

    /// p90 request-level TTFT in ms.
    pub fn ttft_p90_ms(&self) -> f64 {
        self.ttft_hist.percentile(0.90) as f64 / 1e6
    }

    /// p99 request-level TTFT in ms.
    pub fn ttft_p99_ms(&self) -> f64 {
        self.ttft_hist.percentile(0.99) as f64 / 1e6
    }

    /// Median inter-token latency in ms (0.0 with single-token sessions).
    pub fn itl_p50_ms(&self) -> f64 {
        self.itl_hist.percentile(0.50) as f64 / 1e6
    }

    /// p99 inter-token latency in ms.
    pub fn itl_p99_ms(&self) -> f64 {
        self.itl_hist.percentile(0.99) as f64 / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fx::builder::GraphDims;

    #[test]
    fn empty_report_is_sane() {
        let r = ServeReport::from_sessions(&[], 1_000);
        assert_eq!(r.sessions, 0);
        assert_eq!(r.total_tokens, 0);
        assert_eq!(r.agg_tok_per_s, 0.0);
    }

    #[test]
    fn mode_label_and_dispatches_per_round() {
        let mut r = ServeReport::from_sessions(&[], 1_000);
        assert_eq!(r.mode_label(), "eager");
        r.planned = true;
        assert_eq!(r.mode_label(), "planned");
        r.batch_width = 4;
        assert_eq!(r.mode_label(), "planned+batched(w=4)");
        r.prefill_chunk = 16;
        assert_eq!(r.mode_label(), "planned+batched(w=4)+prefill(c=16)");
        // Unified subsumes the batched + prefill labels.
        r.unified = true;
        assert_eq!(r.mode_label(), "planned+unified(w=4,c=16)");
        // Paged residency qualifies the exec mode itself.
        r.kv_block = 16;
        assert_eq!(r.mode_label(), "planned+paged(b=16)+unified(w=4,c=16)");
        r.kv_block = 0;
        // Speculation only labels (and only engages) on the unified path.
        r.speculate = 4;
        assert_eq!(r.mode_label(), "planned+unified(w=4,c=16)+spec(k=4)");
        // Fault injection labels on every path (it rides the device layer,
        // not an execution mode).
        r.fault_seed = Some(7);
        assert_eq!(
            r.mode_label(),
            "planned+unified(w=4,c=16)+spec(k=4)+faults(seed=7)"
        );
        r.fault_seed = None;
        r.speculate = 0;
        r.unified = false;
        r.batch_width = 0;
        assert_eq!(r.mode_label(), "planned+prefill(c=16)");
        r.fault_seed = Some(11);
        assert_eq!(r.mode_label(), "planned+prefill(c=16)+faults(seed=11)");
        r.fault_seed = None;
        r.prefill_chunk = 0;
        r.batch_width = 4;
        // Prefill dispatch-rate helper: 120 dispatches over 32 prompt
        // tokens -> 3.75 per token (vs ~59 token-by-token).
        r.prefill_dispatches = 120;
        r.prefill_steps = 32;
        assert!((r.prefill_dispatches_per_prompt_token() - 3.75).abs() < 1e-9);
        r.dispatches = 236;
        r.rounds = 4;
        assert!((r.dispatches_per_round() - 59.0).abs() < 1e-9);
        r.rounds = 0; // guard: no division by zero
        assert!((r.dispatches_per_round() - 236.0).abs() < 1e-9);
    }

    #[test]
    fn speculative_counters_and_rates() {
        let mut r = ServeReport::from_sessions(&[], 1_000);
        // Nothing drafted: rate is 0, not NaN.
        assert_eq!(r.acceptance_rate(), 0.0);
        r.drafted = 20;
        r.accepted = 15;
        assert!((r.acceptance_rate() - 0.75).abs() < 1e-9);
        r.total_tokens = 18;
        r.rounds = 9;
        assert!((r.tokens_per_round() - 2.0).abs() < 1e-9);
        r.rounds = 0; // guard: no division by zero
        assert!((r.tokens_per_round() - 18.0).abs() < 1e-9);
    }

    #[test]
    fn aggregates_drafted_and_accepted_from_sessions() {
        let dims = GraphDims::qwen_tiny();
        let mut a = SessionState::new(0, vec![1], 2, &dims, 0, 0);
        let mut b = SessionState::new(1, vec![2], 2, &dims, 0, 0);
        a.metrics.drafted = 6;
        a.metrics.accepted = 4;
        b.metrics.drafted = 2;
        b.metrics.accepted = 2;
        let r = ServeReport::from_sessions(&[a, b], 1_000);
        assert_eq!(r.drafted, 8);
        assert_eq!(r.accepted, 6);
        assert!((r.acceptance_rate() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn aggregates_two_sessions() {
        let dims = GraphDims::qwen_tiny();
        let mut a = SessionState::new(0, vec![1], 2, &dims, 0, 0);
        let mut b = SessionState::new(1, vec![2], 2, &dims, 0, 0);
        for s in [&mut a, &mut b] {
            let _ = s.take_input();
            s.note_token(10, 1_000_000);
            let _ = s.take_input();
            s.note_token(11, 2_000_000);
            s.metrics.dispatches = 10;
            s.metrics.steps = 2;
            s.metrics.phase_virtual_ns[7] = 500;
        }
        let r = ServeReport::from_sessions(&[a, b], 2_000_000);
        assert_eq!(r.sessions, 2);
        assert_eq!(r.total_tokens, 4);
        assert_eq!(r.dispatches, 20);
        assert_eq!(r.dispatches_per_step, 5);
        assert_eq!(r.phase_virtual_ns[7], 1000);
        assert!((r.agg_tok_per_s - 2000.0).abs() < 1e-6);
    }
}

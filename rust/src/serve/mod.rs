//! # Multi-session serving engine
//!
//! Serving layer over the dispatch substrate: one shared [`Device`] +
//! [`Registry`] + prepared-pipeline cache drives N concurrent sessions by
//! interleaving decode steps round-robin.
//!
//! ## Scheduling model
//!
//! The scheduler is **continuous batching** (the WebLLM shape). In the
//! planned serving default, rounds with >= 2 active sessions replay the
//! BATCHED plan — sessions occupy sticky decode slots and every layer op
//! is one dispatch per chunk of `batch_width` sessions (the Appendix F
//! amortization; see `ARCHITECTURE.md`'s batched-round lifecycle) — and
//! sessions still ingesting their prompt replay the chunked PREFILL plan
//! instead: one dispatch per layer op per `prefill_chunk` prompt tokens,
//! interleaved with the decode chunks in the same round, with only FINAL
//! prompt chunks joining the round's coalesced readback (see
//! `ARCHITECTURE.md`'s chunked-prefill lifecycle). `--no-batch` /
//! `--prefill-chunk 0` (or eager mode, or a single active session) keep
//! the batch=1 / token-by-token granularity below:
//!
//! 1. **Admit** — requests queue FIFO; up to `max_concurrent` become
//!    active. Exceeding the cap queues, never errors. Planned-mode
//!    admission is cache-aware: a session claims its device-resident
//!    cache set up front, and pool pressure defers admission to a later
//!    round instead of failing mid-encode.
//! 2. **Encode round** — each active session, in admission order, encodes
//!    one decode step through the shared [`GraphExecutor`]: per-op
//!    framework cost + the 8-phase dispatch sequence per kernel node.
//!    Prepared pipelines, bind-group layouts, cached bind groups, pooled
//!    activation buffers, and pinned weight buffers are all shared —
//!    nothing is rebuilt per session or per request (the "Llamas on the
//!    Web" portable-performance rule).
//! 3. **Coalesced finish** — every session's logits buffer is read back
//!    behind ONE synchronization point ([`Device::map_read_many`]); token
//!    selection is host argmax (or the Appendix H device-argmax variant,
//!    which finishes per-session).
//! 4. **Retire** — finished sessions leave immediately; their pooled
//!    buffers — including planned mode's device-resident KV cache sets —
//!    are recycled by the next admit. Back to 1.
//!
//! ## Execution modes and cache residency
//!
//! The serving default is **planned replay** (`ExecMode::serving_default()`):
//! each session owns a device-resident KV cache set (`KvCache::Device`,
//! allocated from the shared bounded pool via `plan::CacheArena`), K/V
//! appends happen on-device through in-place `cache_update` dispatches,
//! and per-step host traffic is just the token embedding + position
//! uniforms (`SessionMetrics::upload_bytes`, table S1). Eager mode stays
//! available (`--exec-mode eager`) and round-trips caches host-side per
//! step — the paper's measured pathology. Sessions can be parked with
//! `ServingEngine::evict_session_cache` (spill to host, release buffers)
//! and resume transparently; `ServingEngine::reset_session` releases the
//! device set AND clears host state.
//!
//! ## How serving throughput relates to the paper's overhead accounting
//!
//! The paper decomposes batch-1 per-operation cost into per-dispatch API
//! overhead (24–36 µs on Vulkan), framework overhead (~59–71 µs), and the
//! per-token GPU→CPU synchronization. Interleaving does **not** amortize
//! the first two — they are paid per dispatch, and each session still
//! issues its full dispatch stream (that wall only falls to fusion or
//! kernel-level batching). What it does amortize is the **fixed per-step
//! cost**: the synchronizing readback's fixed map cost and the GPU-
//! frontier wait are paid once per round instead of once per session, so
//! aggregate tokens/s rises with session count and saturates once
//! per-dispatch costs dominate — the serving-side analogue of the paper's
//! fusion result (`wdb serve-bench` / `benches/t_serving.rs` quantify it).
//! Per-session attribution (dispatch phases via the shared
//! [`PhaseTimeline`] deltas, framework, sync, GPU kernel time) makes that
//! split visible in the report tables.
//!
//! [`Device`]: crate::webgpu::Device
//! [`Registry`]: crate::runtime::Registry
//! [`GraphExecutor`]: crate::engine::GraphExecutor
//! [`Device::map_read_many`]: crate::webgpu::Device::map_read_many
//! [`PhaseTimeline`]: crate::webgpu::PhaseTimeline

// The serving layer is fault-tolerant by contract: every failure path is
// a typed `Error` (transient vs fatal, session- vs device-scoped), never
// a panic. New `unwrap()`/`expect()` sites fail clippy review.
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod draft;
pub mod engine;
pub mod metrics;
pub mod queue;
pub mod session;

pub use draft::draft_ngram;
pub use engine::{argmax_bytes, ServeConfig, ServingEngine, StepHandle};
pub use metrics::ServeReport;
pub use queue::{Request, RequestQueue};
pub use session::{KvCache, SessionMetrics, SessionSnapshot, SessionState};

//! FIFO request queue + admission bookkeeping.
//!
//! Admission control is deliberately simple (the WebLLM/OpenAI-front-end
//! shape): requests past `max_concurrent` queue rather than erroring, and
//! the scheduler admits strictly in arrival order between decode rounds.

use std::collections::VecDeque;

/// One queued generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub n_new: usize,
    /// Virtual clock at submission (TTFT measurements include queueing).
    pub enqueued_ns: u64,
}

/// Strictly-FIFO backlog.
#[derive(Debug, Default)]
pub struct RequestQueue {
    backlog: VecDeque<Request>,
    next_id: u64,
    /// Total requests ever pushed.
    pub submitted: u64,
    /// Total requests ever popped (admitted).
    pub admitted: u64,
}

impl RequestQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue a request; returns its id. Ids are assigned in arrival
    /// order, so FIFO admission implies ids pop in increasing order.
    pub fn push(&mut self, prompt: Vec<usize>, n_new: usize, enqueued_ns: u64) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.submitted += 1;
        self.backlog.push_back(Request { id, prompt, n_new, enqueued_ns });
        id
    }

    pub fn pop(&mut self) -> Option<Request> {
        let r = self.backlog.pop_front();
        if r.is_some() {
            self.admitted += 1;
        }
        r
    }

    pub fn len(&self) -> usize {
        self.backlog.len()
    }

    pub fn is_empty(&self) -> bool {
        self.backlog.is_empty()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_arrival_order() {
        let mut q = RequestQueue::new();
        let a = q.push(vec![1], 1, 0);
        let b = q.push(vec![2], 1, 5);
        let c = q.push(vec![3], 1, 9);
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop().unwrap().id, a);
        assert_eq!(q.pop().unwrap().id, b);
        assert_eq!(q.pop().unwrap().id, c);
        assert!(q.pop().is_none());
        assert_eq!(q.submitted, 3);
        assert_eq!(q.admitted, 3);
    }

    #[test]
    fn ids_are_monotone() {
        let mut q = RequestQueue::new();
        let mut last = None;
        for i in 0..10 {
            let id = q.push(vec![i], 1, i as u64);
            if let Some(l) = last {
                assert!(id > l);
            }
            last = Some(id);
        }
    }
}

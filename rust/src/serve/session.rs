//! Per-session decode state + metrics.
//!
//! This is the state that used to live inside the single-request engine
//! (KV caches, position, pending prompt) split out so the serving engine
//! can interleave many sessions over ONE shared executor: everything GPU-
//! side (device, prepared pipelines, bind-group layouts, buffer pool,
//! pinned weights) is shared; everything here is private to one request.

use crate::fx::builder::GraphDims;
use crate::plan::{DeviceKvCache, PagedKv};
use crate::tensor::Tensor;

/// Where a session's KV caches live.
///
/// - `Host` — one `(K, V)` tensor pair per layer, re-uploaded and read
///   back every decode step (eager mode; also the spilled representation
///   after an evict).
/// - `Device` — a session-owned device-resident cache set updated in
///   place by the plan's `cache_update` dispatches; per-step host traffic
///   is just the token embedding + position uniforms (planned mode).
/// - `Paged` — per-block residency over the engine's shared pool planes
///   (paged planned mode, the serving default): the session owns a block
///   table whose entries are either physical pool block-groups or
///   host-parked block bytes; the pager moves individual blocks, not
///   whole sessions.
///
/// Sessions start `Host` (empty, lazily materialized); a planned engine
/// promotes them to `Device` (or `Paged`) at admission (scheduled
/// sessions, cache-aware: admission defers under pool pressure) or on
/// first encode (detached and evicted sessions, hydrating spilled host
/// state if `pos > 0`), and demotes them on evict/retire.
#[derive(Debug, Clone)]
pub enum KvCache {
    Host(Vec<(Tensor, Tensor)>),
    Device(DeviceKvCache),
    Paged(PagedKv),
}

impl KvCache {
    pub fn host_zeroed(dims: &GraphDims) -> Self {
        let shape = vec![dims.max_seq, dims.kv_heads, dims.head_dim];
        KvCache::Host(
            (0..dims.layers)
                .map(|_| (Tensor::zeros_f32(shape.clone()), Tensor::zeros_f32(shape.clone())))
                .collect(),
        )
    }

    pub fn is_device(&self) -> bool {
        matches!(self, KvCache::Device(_))
    }

    pub fn is_paged(&self) -> bool {
        matches!(self, KvCache::Paged(_))
    }

    pub fn as_device(&self) -> Option<&DeviceKvCache> {
        match self {
            KvCache::Device(c) => Some(c),
            _ => None,
        }
    }

    pub fn as_paged(&self) -> Option<&PagedKv> {
        match self {
            KvCache::Paged(p) => Some(p),
            _ => None,
        }
    }

    pub fn as_paged_mut(&mut self) -> Option<&mut PagedKv> {
        match self {
            KvCache::Paged(p) => Some(p),
            _ => None,
        }
    }

    pub fn as_host(&self) -> Option<&Vec<(Tensor, Tensor)>> {
        match self {
            KvCache::Host(c) => Some(c),
            _ => None,
        }
    }

    pub fn as_host_mut(&mut self) -> Option<&mut Vec<(Tensor, Tensor)>> {
        match self {
            KvCache::Host(c) => Some(c),
            _ => None,
        }
    }

    /// Device bytes held by this cache (0 while host-resident). Paged
    /// sessions need the engine's block-group size:
    /// `PagedKv::resident_bytes(group_bytes)` — this shape-free accessor
    /// reports 0 for them, and the serving report sums paged residency
    /// through the block arena instead.
    pub fn resident_bytes(&self) -> usize {
        match self {
            KvCache::Device(c) => c.resident_bytes,
            KvCache::Host(_) | KvCache::Paged(_) => 0,
        }
    }
}

/// Timing/attribution metrics for one session, in virtual nanoseconds of
/// the shared device clock.
#[derive(Debug, Clone, Default)]
pub struct SessionMetrics {
    /// Clock when the request entered the queue.
    pub enqueued_ns: u64,
    /// Clock when the scheduler admitted it (became active).
    pub admitted_ns: u64,
    /// Clock when the first generated token was selected (the paper's
    /// TTFT measurement point: prefill + first decode step + sync).
    pub first_token_ns: u64,
    /// Clock when the encode that consumed the FINAL prompt token
    /// finished (chunked prefill: the final chunk's replay; token-by-token:
    /// the last prompt step's encode). TTFT splits at this point into
    /// prompt ingestion ([`SessionMetrics::prefill_ns`]) and the first
    /// token's readback/sync ([`SessionMetrics::first_decode_ns`]).
    pub prefill_end_ns: u64,
    /// Clock when the last requested token was produced.
    pub finished_ns: u64,
    /// Clock when the most recent token was produced (per-token deltas).
    pub last_token_ns: u64,
    /// Decode steps executed (prefill + generation).
    pub steps: u64,
    /// Steps that consumed a prompt token.
    pub prefill_steps: u64,
    /// WebGPU dispatches attributed to this session.
    pub dispatches: u64,
    /// Dispatches issued during prefill steps.
    pub prefill_dispatches: u64,
    /// Per-phase dispatch CPU cost attributed to this session, in
    /// `DISPATCH_PHASES` order (from `PhaseTimeline` deltas around this
    /// session's encodes).
    pub phase_virtual_ns: [u64; 8],
    /// Framework (per-op) overhead attributed to this session.
    pub framework_virtual_ns: u64,
    /// Synchronization (readback/map) cost attributed to this session; a
    /// coalesced multi-session readback is split across its participants.
    pub sync_virtual_ns: u64,
    /// GPU kernel time enqueued by this session's dispatches.
    pub kernel_virtual_ns: u64,
    /// Encode-side CPU cost of this session's steps (uploads + dispatch
    /// phases + framework). In planned mode this is the session's share of
    /// plan *replay* cost — the per-session counterpart of the engine-
    /// level one-time plan-build cost in [`crate::serve::ServeReport`].
    pub encode_virtual_ns: u64,
    /// Host->device bytes uploaded by this session's encodes (the paper's
    /// per-step host traffic: with device-resident caches this is just the
    /// token embedding + position uniforms; eager mode re-uploads every
    /// activation and both caches per step).
    pub upload_bytes: u64,
    /// Speculative decode: draft tokens submitted to verify rounds.
    pub drafted: u64,
    /// Paged KV: high-water block-table length (blocks the session's
    /// residency passes granted or promised; 0 in contiguous mode).
    pub kv_blocks_hw: u64,
    /// Paged KV: high-water count of this session's blocks parked on the
    /// host at once (pager page-outs or a full quarantine spill).
    pub kv_blocks_spilled_hw: u64,
    /// Speculative decode: draft tokens accepted (greedy-matched). The
    /// per-session acceptance rate is `accepted / drafted`.
    pub accepted: u64,
    /// Per generated token: [TTFT, then per-decode-step deltas].
    pub per_token_ns: Vec<u64>,
}

impl SessionMetrics {
    /// Request-level time to first token (includes queueing).
    pub fn ttft_ns(&self) -> u64 {
        self.first_token_ns.saturating_sub(self.enqueued_ns)
    }

    /// Prompt-ingestion latency: admission to the encode that consumed
    /// the final prompt token (the part chunked prefill collapses).
    pub fn prefill_ns(&self) -> u64 {
        self.prefill_end_ns.saturating_sub(self.admitted_ns)
    }

    /// First-decode latency: end of prompt ingestion to the first
    /// generated token's selection (the readback/sync side of TTFT).
    pub fn first_decode_ns(&self) -> u64 {
        self.first_token_ns.saturating_sub(self.prefill_end_ns)
    }

    /// Total dispatch-phase CPU cost.
    pub fn phase_total_ns(&self) -> u64 {
        self.phase_virtual_ns.iter().sum()
    }

    pub fn generation_ns(&self) -> u64 {
        self.finished_ns.saturating_sub(self.admitted_ns)
    }
}

/// The committed logical cursor of a session — everything a transient
/// fault can dirty. Captured by [`SessionState::snapshot`] before each
/// fallible encode, restored by [`SessionState::rollback`] on failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionSnapshot {
    pub pos: usize,
    fed: usize,
}

/// One in-flight request's decode state.
#[derive(Debug, Clone)]
pub struct SessionState {
    pub id: u64,
    pub prompt: Vec<usize>,
    /// Number of tokens to generate; the session retires once reached.
    pub n_new: usize,
    /// Per-layer KV caches — the session-private half of the state split;
    /// each layer's K/V is `[max_seq, kv_heads, head_dim]`. Host-resident
    /// in eager mode, a [`DeviceKvCache`] handle in planned mode.
    pub kv: KvCache,
    /// Current decode position (rows of the cache that are valid).
    pub pos: usize,
    /// Cache-write high-water: the highest `rows_end` a SUCCESSFUL replay
    /// scattered for this session (committed speculative draft rows
    /// included). Monotonic — a rewind moves `pos` back but never `kv_hw`,
    /// so spill reconstruction knows exactly which block rows hold real
    /// device bytes (rows `>= kv_hw` are zeros by construction, matching
    /// the contiguous cache's zeroed tail bit-for-bit).
    pub kv_hw: usize,
    /// Sticky decode-slot index (batched serving): assigned at admission,
    /// freed only on retire, so ragged retirement never reshuffles the
    /// surviving sessions' rows in the batched cache-set table. `None`
    /// for detached sessions (single-request `Engine` driving).
    pub slot: Option<usize>,
    /// Prompt tokens consumed so far.
    fed: usize,
    /// Most recent output token (the next step's input once the prompt is
    /// exhausted).
    pub last_token: Option<usize>,
    /// Generated tokens (excludes prompt-echo; index 0 is the token
    /// produced by the step that consumed the final prompt token).
    pub tokens: Vec<usize>,
    pub metrics: SessionMetrics,
    /// Consecutive transient faults charged to this session's current
    /// recovery episode; reset to 0 by a successfully committed step.
    pub retries: u32,
    /// Lifetime transient faults recovered by this session (sticky; a
    /// retired session with `total_retries > 0 && !failed` counts as
    /// recovered in the serve report).
    pub total_retries: u64,
    /// Degradation-ladder rung, latched until retire: 0 = unified rounds,
    /// 1 = split scheduling (solo prefill chunk / solo decode step),
    /// 2 = interleaved token-by-token. Escalates one rung per fault.
    pub degrade: u8,
    /// Quarantine backoff: rounds this session sits out before its next
    /// retry (decremented once per round while positive).
    pub cooldown: u32,
    /// Set once `retries` exceeds the engine's bound: the session is
    /// abandoned and retired with whatever tokens it committed.
    pub failed: bool,
}

impl SessionState {
    pub fn new(
        id: u64,
        prompt: Vec<usize>,
        n_new: usize,
        dims: &GraphDims,
        enqueued_ns: u64,
        admitted_ns: u64,
    ) -> Self {
        let _ = dims; // cache layout comes from the engine at first encode
        SessionState {
            id,
            prompt,
            n_new,
            // Lazily materialized: the engine promotes to a device cache
            // set (planned, the serving default) or fills in zeroed host
            // tensors (eager) on the first encode — a fresh session should
            // not pay the O(layers x max_seq) host allocation it may never
            // read.
            kv: KvCache::Host(Vec::new()),
            pos: 0,
            kv_hw: 0,
            slot: None,
            fed: 0,
            last_token: None,
            tokens: Vec::new(),
            metrics: SessionMetrics {
                enqueued_ns,
                admitted_ns,
                ..SessionMetrics::default()
            },
            retries: 0,
            total_retries: 0,
            degrade: 0,
            cooldown: 0,
            failed: false,
        }
    }

    /// Capture the committed logical cursor — decode position and prompt
    /// cursor — before a fallible encode. KV rows at or beyond `pos` are
    /// dead (never attended by causal SDPA, overwritten by the next
    /// committed scatter), so `{pos, fed}` alone is a complete
    /// checkpoint: [`SessionState::rollback`] plus the spill/re-hydrate
    /// path restores exactly the last committed token's state.
    pub fn snapshot(&self) -> SessionSnapshot {
        SessionSnapshot { pos: self.pos, fed: self.fed }
    }

    /// Rewind to a [`SessionState::snapshot`] taken before a failed
    /// encode. Token history and `last_token` are untouched: a fault is
    /// only ever observed before the round's readback commits tokens.
    pub fn rollback(&mut self, snap: SessionSnapshot) {
        self.pos = snap.pos;
        self.fed = snap.fed;
    }

    /// Reset this session's host-side decode state: position, prompt
    /// cursor, token history, and the cache contents (the KV cache reverts
    /// to the lazily-materialized empty state, so the next encode starts
    /// from zeroed caches in either mode).
    ///
    /// This is only HALF of a full reset: a device-resident cache set (or
    /// a paged block table's resident groups) must also be released back
    /// to its allocator — use
    /// [`crate::serve::ServingEngine::reset_session`], which does both and
    /// asserts nothing leaks via the pool's high-water stats. Calling this
    /// directly on a device-resident session would strand its buffers, so
    /// it downgrades to the empty host state and returns the old cache
    /// for the caller to release.
    pub fn reset_host(&mut self) -> KvCache {
        let old = std::mem::replace(&mut self.kv, KvCache::Host(Vec::new()));
        self.pos = 0;
        self.kv_hw = 0;
        self.fed = 0;
        self.last_token = None;
        self.tokens.clear();
        old
    }

    /// The next input token: unconsumed prompt tokens first, then the most
    /// recent output. Returns `(token, consumed_a_prompt_token)`; `None`
    /// only for a promptless session that has not produced anything yet.
    pub fn take_input(&mut self) -> Option<(usize, bool)> {
        if self.fed < self.prompt.len() {
            let t = self.prompt[self.fed];
            self.fed += 1;
            Some((t, true))
        } else {
            self.last_token.map(|t| (t, false))
        }
    }

    /// True while this step's input still comes from the prompt.
    pub fn in_prefill(&self) -> bool {
        self.fed < self.prompt.len()
    }

    /// Unconsumed prompt tokens.
    pub fn remaining_prompt(&self) -> usize {
        self.prompt.len() - self.fed
    }

    /// The next up-to-`max` unconsumed prompt token indices (empty once
    /// the prompt is exhausted). Read-only: pair with
    /// [`SessionState::consume_prompt`] once the chunk's encode succeeds.
    pub fn peek_prompt_chunk(&self, max: usize) -> std::ops::Range<usize> {
        let take = max.min(self.remaining_prompt());
        self.fed..self.fed + take
    }

    /// Mark `n` prompt tokens consumed — the chunked-prefill counterpart
    /// of [`SessionState::take_input`]'s one-token advance.
    pub fn consume_prompt(&mut self, n: usize) {
        self.fed = (self.fed + n).min(self.prompt.len());
    }

    pub fn finished(&self) -> bool {
        self.tokens.len() >= self.n_new
    }

    /// Record a produced token at virtual time `now`. Tokens produced
    /// before the whole prompt is consumed are intermediate prefill logits
    /// and are not part of the generated stream (matching the single-
    /// request engine's accounting).
    pub fn note_token(&mut self, token: usize, now: u64) {
        self.last_token = Some(token);
        if self.fed < self.prompt.len() {
            return; // intermediate prefill output, unused
        }
        if self.tokens.is_empty() {
            self.metrics.first_token_ns = now;
            self.metrics
                .per_token_ns
                .push(now.saturating_sub(self.metrics.admitted_ns));
        } else {
            self.metrics
                .per_token_ns
                .push(now.saturating_sub(self.metrics.last_token_ns));
        }
        self.metrics.last_token_ns = now;
        self.tokens.push(token);
        if self.finished() {
            self.metrics.finished_ns = now;
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn session(prompt: Vec<usize>, n_new: usize) -> SessionState {
        SessionState::new(1, prompt, n_new, &GraphDims::qwen_tiny(), 100, 100)
    }

    #[test]
    fn prompt_feeds_before_generated_tokens() {
        let mut s = session(vec![7, 8], 2);
        assert_eq!(s.take_input(), Some((7, true)));
        s.note_token(42, 200); // intermediate prefill output
        assert!(s.tokens.is_empty());
        assert_eq!(s.take_input(), Some((8, true)));
        s.note_token(43, 300); // consumed last prompt token -> first gen
        assert_eq!(s.tokens, vec![43]);
        assert_eq!(s.metrics.first_token_ns, 300);
        assert_eq!(s.take_input(), Some((43, false)));
        s.note_token(44, 450);
        assert!(s.finished());
        assert_eq!(s.metrics.finished_ns, 450);
        assert_eq!(s.metrics.per_token_ns, vec![200, 150]);
    }

    #[test]
    fn prompt_chunks_feed_then_note_first_token() {
        let mut s = session(vec![10, 11, 12, 13, 14], 2);
        assert_eq!(s.remaining_prompt(), 5);
        let r = s.peek_prompt_chunk(4);
        assert_eq!(r, 0..4);
        s.consume_prompt(r.len());
        assert!(s.in_prefill(), "one prompt token left");
        // Ragged tail: only 1 token remains however large the chunk.
        let r = s.peek_prompt_chunk(4);
        assert_eq!(r, 4..5);
        s.consume_prompt(r.len());
        assert!(!s.in_prefill());
        // The final chunk's last-row logits select the first generated
        // token — note_token now records it.
        s.note_token(42, 900);
        assert_eq!(s.tokens, vec![42]);
        assert_eq!(s.metrics.first_token_ns, 900);
        // Prefill/first-decode split helpers.
        s.metrics.prefill_end_ns = 700;
        assert_eq!(s.metrics.prefill_ns(), 600); // admitted at 100
        assert_eq!(s.metrics.first_decode_ns(), 200);
    }

    #[test]
    fn promptless_session_has_no_input() {
        let mut s = session(vec![], 1);
        assert_eq!(s.take_input(), None);
        s.note_token(9, 150);
        assert_eq!(s.take_input(), Some((9, false)));
    }

    #[test]
    fn fresh_sessions_defer_cache_materialization() {
        // Sessions are born with the empty host placeholder: planned mode
        // (the serving default) promotes straight to a device cache set
        // without ever paying the O(layers x max_seq) host allocation.
        let s = session(vec![1], 1);
        assert!(s.kv.as_host().expect("fresh sessions are host-resident").is_empty());
        assert_eq!(s.kv.resident_bytes(), 0);
        // The eager materialization helper carries the full per-dims shape.
        let d = GraphDims::qwen_tiny();
        let host = KvCache::host_zeroed(&d);
        let host = host.as_host().unwrap();
        assert_eq!(host.len(), d.layers);
        assert_eq!(host[0].0.shape, vec![d.max_seq, d.kv_heads, d.head_dim]);
    }

    #[test]
    fn reset_host_clears_decode_state() {
        let d = GraphDims::qwen_tiny();
        let mut s = session(vec![7, 8], 2);
        let _ = s.take_input();
        s.note_token(1, 100);
        let _ = s.take_input();
        s.note_token(2, 200);
        s.pos = 2;
        s.kv = KvCache::host_zeroed(&d); // materialized (eager path)...
        if let Some(host) = s.kv.as_host_mut() {
            host[0].0 = Tensor::f32(vec![1], vec![5.0]).unwrap(); // ...and dirty
        }
        let old = s.reset_host();
        assert!(
            old.as_device().is_none() && old.as_paged().is_none(),
            "host session has no device cache to hand back"
        );
        assert_eq!(s.pos, 0);
        assert!(s.tokens.is_empty());
        assert_eq!(s.take_input(), Some((7, true)), "prompt cursor rewound");
        let host = s.kv.as_host().unwrap();
        assert!(host.is_empty(), "reset reverts to the lazily-materialized state");
    }

    #[test]
    fn snapshot_rollback_rewinds_the_logical_cursor() {
        let mut s = session(vec![10, 11, 12], 2);
        let r = s.peek_prompt_chunk(2);
        s.consume_prompt(r.len());
        s.pos += 2;
        let snap = s.snapshot();
        // A failed chunk: prompt cursor and position advanced, then the
        // replay faulted before the readback.
        let r = s.peek_prompt_chunk(2);
        s.consume_prompt(r.len());
        s.pos += 1;
        s.rollback(snap);
        assert_eq!(s.pos, 2);
        assert_eq!(s.remaining_prompt(), 1, "prompt cursor rewound too");
        // The retry re-reads the same chunk.
        assert_eq!(s.peek_prompt_chunk(2), 2..3);
    }

    #[test]
    fn fresh_sessions_start_healthy() {
        let s = session(vec![1], 1);
        assert_eq!(s.retries, 0);
        assert_eq!(s.total_retries, 0);
        assert_eq!(s.degrade, 0);
        assert_eq!(s.cooldown, 0);
        assert!(!s.failed);
    }

    #[test]
    fn ttft_includes_queueing() {
        let mut s = SessionState::new(1, vec![5], 1, &GraphDims::qwen_tiny(), 50, 80);
        let _ = s.take_input();
        s.note_token(1, 130);
        assert_eq!(s.metrics.ttft_ns(), 80); // 130 - enqueued 50
        assert_eq!(s.metrics.per_token_ns, vec![50]); // 130 - admitted 80
    }
}

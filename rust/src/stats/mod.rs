//! Statistics: the paper's reporting machinery — mean ± std, 95% CI via
//! the t-distribution, coefficient of variation (§3.3), and Welch's t-test
//! p-values (Tables 5/11/15/19 report significance).
//!
//! No external crates: the t CDF comes from the regularized incomplete beta
//! function (continued-fraction evaluation, Numerical Recipes style).

pub mod welch;

pub use welch::{welch_t_test, WelchResult};

/// Descriptive summary of a sample.
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub ci95_lo: f64,
    pub ci95_hi: f64,
    /// Coefficient of variation, sigma / mu.
    pub cv: f64,
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n - 1 denominator).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let ss: f64 = xs.iter().map(|x| (x - m) * (x - m)).sum();
    (ss / (xs.len() - 1) as f64).sqrt()
}

/// Two-sided 97.5% t-critical value for `df` degrees of freedom.
/// Exact table for small df, asymptotic beyond.
pub fn t_critical_975(df: f64) -> f64 {
    const TABLE: [(f64, f64); 14] = [
        (1.0, 12.706), (2.0, 4.303), (3.0, 3.182), (4.0, 2.776),
        (5.0, 2.571), (6.0, 2.447), (7.0, 2.365), (8.0, 2.306),
        (9.0, 2.262), (10.0, 2.228), (15.0, 2.131), (20.0, 2.086),
        (29.0, 2.045), (30.0, 2.042),
    ];
    if df <= 0.0 {
        return f64::NAN;
    }
    if df >= 100.0 {
        return 1.984; // ~z for practical sample sizes
    }
    // linear interpolation over the table
    let mut prev = TABLE[0];
    for &(d, t) in &TABLE {
        if df <= d {
            if (d - prev.0).abs() < 1e-12 {
                return t;
            }
            let w = (df - prev.0) / (d - prev.0);
            return prev.1 + w * (t - prev.1);
        }
        prev = (d, t);
    }
    // 30 < df < 100
    let w = (df - 30.0) / 70.0;
    2.042 + w * (1.984 - 2.042)
}

pub fn summarize(xs: &[f64]) -> Summary {
    let n = xs.len();
    let m = mean(xs);
    let s = std_dev(xs);
    let (lo, hi) = if n >= 2 {
        let t = t_critical_975((n - 1) as f64);
        let half = t * s / (n as f64).sqrt();
        (m - half, m + half)
    } else {
        (m, m)
    };
    Summary {
        n,
        mean: m,
        std: s,
        ci95_lo: lo,
        ci95_hi: hi,
        cv: if m.abs() > 1e-300 { s / m } else { f64::NAN },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn t_critical_matches_tables() {
        assert!((t_critical_975(1.0) - 12.706).abs() < 1e-3);
        assert!((t_critical_975(9.0) - 2.262).abs() < 1e-3);
        assert!((t_critical_975(29.0) - 2.045).abs() < 1e-3);
        assert!(t_critical_975(500.0) < 2.0);
    }

    #[test]
    fn ci_contains_mean_and_tightens_with_n() {
        let small: Vec<f64> = (0..5).map(|i| 10.0 + i as f64).collect();
        let large: Vec<f64> = (0..50).map(|i| 10.0 + (i % 5) as f64).collect();
        let s1 = summarize(&small);
        let s2 = summarize(&large);
        assert!(s1.ci95_lo < s1.mean && s1.mean < s1.ci95_hi);
        assert!((s2.ci95_hi - s2.ci95_lo) < (s1.ci95_hi - s1.ci95_lo));
    }

    #[test]
    fn cv_is_relative() {
        let xs = [100.0, 102.0, 98.0, 101.0, 99.0];
        let s = summarize(&xs);
        assert!(s.cv > 0.0 && s.cv < 0.05);
    }

    #[test]
    fn single_sample_degenerates() {
        let s = summarize(&[42.0]);
        assert_eq!(s.mean, 42.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.ci95_lo, s.ci95_hi);
    }
}

//! Welch's unequal-variance t-test with a two-sided p-value.
//!
//! p = I_{df/(df+t^2)}(df/2, 1/2) — the regularized incomplete beta
//! function, evaluated by Lentz's continued fraction.

use super::{mean, std_dev};

#[derive(Debug, Clone, Copy)]
pub struct WelchResult {
    pub t: f64,
    pub df: f64,
    /// Two-sided p-value.
    pub p: f64,
    pub mean_a: f64,
    pub mean_b: f64,
}

/// ln Gamma (Lanczos approximation).
fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 6] = [
        76.18009172947146,
        -86.50532032941677,
        24.01409824083091,
        -1.231739572450155,
        0.1208650973866179e-2,
        -0.5395239384953e-5,
    ];
    let mut y = x;
    let tmp = x + 5.5 - (x + 0.5) * (x + 5.5).ln();
    let mut ser = 1.000000000190015;
    for g in G {
        y += 1.0;
        ser += g / y;
    }
    -tmp + (2.5066282746310005 * ser / x).ln()
}

/// Continued fraction for the incomplete beta function.
fn betacf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_IT: usize = 200;
    const EPS: f64 = 3e-14;
    const FPMIN: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < FPMIN {
        d = FPMIN;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_IT {
        let m = m as f64;
        let m2 = 2.0 * m;
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        h *= d * c;
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Regularized incomplete beta I_x(a, b).
pub fn betai(a: f64, b: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let bt = (ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b)
        + a * x.ln()
        + b * (1.0 - x).ln())
    .exp();
    if x < (a + 1.0) / (a + b + 2.0) {
        bt * betacf(a, b, x) / a
    } else {
        1.0 - bt * betacf(b, a, 1.0 - x) / b
    }
}

/// Two-sided p-value of a t statistic with `df` degrees of freedom.
pub fn t_p_value(t: f64, df: f64) -> f64 {
    if !t.is_finite() || df <= 0.0 {
        return f64::NAN;
    }
    betai(df / 2.0, 0.5, df / (df + t * t))
}

pub fn welch_t_test(a: &[f64], b: &[f64]) -> WelchResult {
    let (ma, mb) = (mean(a), mean(b));
    let (sa, sb) = (std_dev(a), std_dev(b));
    let (na, nb) = (a.len() as f64, b.len() as f64);
    let va = sa * sa / na;
    let vb = sb * sb / nb;
    let se = (va + vb).sqrt();
    let t = if se > 0.0 { (ma - mb) / se } else { f64::INFINITY };
    let df = if va + vb > 0.0 {
        (va + vb) * (va + vb)
            / (va * va / (na - 1.0).max(1.0) + vb * vb / (nb - 1.0).max(1.0))
    } else {
        (na + nb - 2.0).max(1.0)
    };
    WelchResult { t, df, p: t_p_value(t, df), mean_a: ma, mean_b: mb }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn betai_endpoints() {
        assert_eq!(betai(2.0, 3.0, 0.0), 0.0);
        assert_eq!(betai(2.0, 3.0, 1.0), 1.0);
        // I_0.5(0.5, 0.5) = 0.5 by symmetry
        assert!((betai(0.5, 0.5, 0.5) - 0.5).abs() < 1e-10);
    }

    #[test]
    fn p_value_matches_known_t() {
        // t = 2.0, df = 10 -> p ~ 0.0734 (two-sided)
        let p = t_p_value(2.0, 10.0);
        assert!((p - 0.0734).abs() < 1e-3, "p = {p}");
        // t = 0 -> p = 1
        assert!((t_p_value(0.0, 10.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn identical_samples_not_significant() {
        let a = [5.0, 5.1, 4.9, 5.05, 4.95];
        let r = welch_t_test(&a, &a);
        assert!(r.p > 0.99);
    }

    #[test]
    fn separated_samples_significant() {
        let a = [10.0, 10.1, 9.9, 10.05, 9.95, 10.02, 9.98, 10.01];
        let b = [12.0, 12.1, 11.9, 12.05, 11.95, 12.02, 11.98, 12.01];
        let r = welch_t_test(&a, &b);
        assert!(r.p < 1e-6, "p = {}", r.p);
        assert!(r.t < 0.0); // a < b
    }

    #[test]
    fn overlapping_samples_not_significant() {
        let a = [10.0, 11.0, 9.0, 10.5, 9.5];
        let b = [10.2, 11.2, 9.2, 10.7, 9.7];
        let r = welch_t_test(&a, &b);
        assert!(r.p > 0.5, "p = {}", r.p);
    }

    #[test]
    fn welch_df_between_min_and_sum() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let r = welch_t_test(&a, &b);
        assert!(r.df >= 3.0 && r.df <= 8.0, "df = {}", r.df);
    }
}

//! Analysis tables: 4 (overhead accounting), 10 (FX census), 13 (WebLLM),
//! 14 (crossover), 15 (device argmax).

use crate::baselines::table13 as webllm_rows;
use crate::crossover::{table14_rows, CrossoverModel};
use crate::engine::overhead::OverheadAccounting;
use crate::fx::builder::GraphDims;
use crate::fx::census::Census;
use crate::report::table::{f1, f2, TableDoc};
use crate::webgpu::ImplementationProfile;
use crate::Result;

pub fn table4() -> Result<TableDoc> {
    // Paper inputs: TTFT 71.4 -> 41.6 ms, 876 -> 564 dispatches, Dawn 23.8 us.
    let a = OverheadAccounting::derive(41.6, 71.4, 564, 876, 23.8);
    let hi = OverheadAccounting::derive(41.6, 71.4, 564, 876, 36.0);
    let mut t = TableDoc::new(
        "T4",
        "Approximate TTFT overhead accounting (fused torch-webgpu model, \
         RTX 5090/Dawn, Qwen2.5-0.5B)",
        &["Quantity", "Value (ms)", "Type", "Source"],
    );
    t.section("Directly measured");
    t.row(vec!["TTFT (fused)".into(), f1(a.ttft_fused_ms), "Measured".into(),
               "End-to-end benchmark".into()]);
    t.row(vec!["TTFT (unfused)".into(), f1(a.ttft_unfused_ms), "Measured".into(),
               "End-to-end benchmark".into()]);
    t.row(vec!["Per-dispatch cost".into(), format!("{:.3}", a.per_dispatch_us / 1e3),
               "Measured".into(), "Sequential dispatch (wdb table 6)".into()]);
    t.section("Well-constrained derived quantity");
    t.row(vec!["Per-operation overhead".into(), format!("{:.3}", a.per_op_overhead_us / 1e3),
               "Derived".into(),
               format!("({} - {}) / {} fewer ops", a.ttft_unfused_ms, a.ttft_fused_ms,
                       a.dispatches_unfused - a.dispatches_fused)]);
    t.section("Estimates (~30% uncertainty)");
    t.row(vec!["WebGPU dispatch component".into(),
               format!("{}-{}", f1(a.dispatch_component_ms), f1(hi.dispatch_component_ms)),
               "Estimated".into(), "564 ops x (24-36 us)".into()]);
    t.row(vec!["Framework component".into(),
               format!("{}-{}", f1(hi.framework_component_ms), f1(a.framework_component_ms)),
               "Estimated".into(), "564 ops x (per-op - dispatch) us".into()]);
    t.row(vec!["GPU/CPU overlap".into(), format!("~{}", f1(a.overlap_residual_ms)),
               "Residual".into(), "components - measured TTFT".into()]);
    let (lo, hi_s) = a.sensitivity(0.20);
    t.note(&format!(
        "Sensitivity (Appendix G): +/-20% per-op overhead moves the framework \
         estimate to {:.0}-{:.0} ms; the qualitative ordering is unchanged.",
        lo, hi_s
    ));
    Ok(t)
}

pub fn table10() -> Result<TableDoc> {
    let c = Census::for_dims(&GraphDims::qwen25_05b());
    let mut t = TableDoc::new(
        "T10",
        "FX graph operation breakdown, Qwen2.5-0.5B (sum = 876 compute ops)",
        &["Category", "Operations", "Count"],
    );
    let rows: Vec<(&str, &str, usize)> = vec![
        ("Linear (matmul)", "Q, K, V, O proj, MLP, lm head", c.compute.linear),
        ("Multiply", "RMSNorm weights, MLP gate, rotary", c.compute.multiply),
        ("Add", "Residuals, eps, rotary", c.compute.add),
        ("SDPA", "Attention per layer", c.compute.sdpa),
        ("SiLU", "MLP activation", c.compute.silu),
        ("RMSNorm components", "pow, mean, rsqrt", c.compute.rms_components),
        ("Concatenation", "KV cache, rotary", c.compute.concat),
        ("Other", "neg, embedding, index", c.compute.other),
    ];
    for (cat, ops, n) in rows {
        t.row(vec![cat.into(), ops.into(), n.to_string()]);
    }
    t.row(vec!["Total compute ops".into(), String::new(), c.compute.total().to_string()]);
    t.row(vec!["Shape ops (no dispatch)".into(), "view/reshape/slice".into(),
               c.shape_ops.to_string()]);
    t.row(vec!["Placeholder/output".into(), String::new(),
               c.placeholders_outputs.to_string()]);
    t.row(vec!["Other metadata".into(), String::new(), c.metadata.to_string()]);
    t.row(vec!["Total FX nodes".into(), String::new(), c.total_nodes().to_string()]);
    t.note("Structural derivation — see fx::census for the per-layer formulae.");
    Ok(t)
}

pub fn table13() -> Result<TableDoc> {
    let mut t = TableDoc::new(
        "T13",
        "Browser end-to-end LLM inference via WebLLM-style engine (q4f16, \
         decode tok/s; simulated from dispatch profiles + TVM-fused op counts)",
        &["Platform", "Browser", "Model", "Decode (tok/s)", "Prefill (tok/s)", "Backend"],
    );
    let mut platform = String::new();
    for (i, r) in webllm_rows().iter().enumerate() {
        if r.model.platform != platform {
            platform = r.model.platform.clone();
            t.section(&format!("{platform}"));
        }
        let s = r.model.summary(10, 1300 + i as u64);
        t.row(vec![
            r.model.platform.clone(),
            r.browser.clone(),
            r.qwen.to_string(),
            format!("{} +/- {:.1}", f1(s.mean), s.std),
            format!("~{}", f1(r.prefill_tok_s)),
            r.backend.to_string(),
        ]);
    }
    t.note(
        "WebLLM's advantage over torch-webgpu (~2.4x) decomposes as: \
         aggressive TVM fusion (~200 dispatches vs 564), zero Python \
         framework overhead, and q4f16 kernels. Firefox rows sit at the \
         rate-limit floor regardless of hardware.",
    );
    Ok(t)
}

pub fn table14() -> Result<TableDoc> {
    let model = CrossoverModel::paper();
    let mut t = TableDoc::new(
        "T14",
        "Dispatch-bound crossover batch size B* for representative operations",
        &["Operation", "Dimensions (d_in x d_out)", "B* (computed)", "Regime at B=1"],
    );
    for (group, rows) in table14_rows(&model) {
        t.section(&group);
        for r in rows {
            t.row(vec![
                r.operation,
                format!("{}x{}", r.d_in, r.d_out),
                r.b_star.to_string(),
                r.regime_b1.to_string(),
            ]);
        }
    }
    t.note(&format!(
        "B* = (T_overhead x throughput) / (2 d_in d_out) with T_overhead = \
         {} us, throughput = {} TFLOP/s. At batch=1 every operation is \
         overhead-bound (B* >= 7): the roofline-style statement of the \
         paper's thesis.",
        model.overhead_us, model.throughput_tflops
    ));
    Ok(t)
}

pub fn table15() -> Result<TableDoc> {
    let mut t = TableDoc::new(
        "T15",
        "Device-side argmax vs full readback (substrate map-cost model; \
         paper p-values quoted — both verdicts inconclusive)",
        &["Platform", "Full readback (ms)", "Device argmax (ms)", "Improvement",
          "p (paper)", "Verdict"],
    );
    let vocab_bytes = 151_936usize * 4;
    for (profile, p_paper) in [
        (ImplementationProfile::wgpu_vulkan_rtx5090(), 0.35),
        (ImplementationProfile::wgpu_metal_m2(), 0.62),
    ] {
        // Full readback: map fixed + per-byte over the logits row.
        let full_ms =
            (profile.map_fixed_ns as f64 + vocab_bytes as f64 * profile.map_per_byte_ns) / 1e6;
        // Device argmax: one extra dispatch + 4-byte map.
        let dev_ms = (profile.sequential_dispatch_ns() as f64
            + profile.map_fixed_ns as f64
            + 4.0 * profile.map_per_byte_ns)
            / 1e6;
        let improvement = (full_ms - dev_ms) / full_ms * 100.0;
        t.row(vec![
            profile.name.to_string(),
            f2(full_ms),
            f2(dev_ms),
            format!("{improvement:+.0}%"),
            format!("{p_paper:.2}"),
            "Inconclusive".into(),
        ]);
    }
    t.note(
        "Vulkan's low fixed map cost (~0.1 ms) leaves room for the transfer \
         reduction to show; Metal's ~1.6 ms fixed map cost swamps it — the \
         Appendix H explanation. Run `wdb e2e --device-argmax` to execute \
         both paths for real on the tiny config.",
    );
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_accounting_reproduces_paper() {
        let t = table4().unwrap();
        let md = t.to_markdown();
        assert!(md.contains("0.095") || md.contains("0.096"), "{md}");
        assert!(md.contains("41.6") && md.contains("71.4"));
    }

    #[test]
    fn table10_totals() {
        let t = table10().unwrap();
        let md = t.to_markdown();
        assert!(md.contains("876"));
        assert!(md.contains("1911"));
    }

    #[test]
    fn table14_regimes() {
        let t = table14().unwrap();
        for row in t.rows.iter().filter(|r| !r[0].starts_with("**")) {
            assert_eq!(row[3], "Overhead-bound");
        }
    }

    #[test]
    fn table15_metal_gains_nothing() {
        let t = table15().unwrap();
        let vulkan_imp: f64 = t.rows[0][3]
            .trim_end_matches('%')
            .parse()
            .unwrap();
        let metal_imp: f64 = t.rows[1][3].trim_end_matches('%').parse().unwrap();
        assert!(vulkan_imp > 50.0, "vulkan {vulkan_imp}");
        assert!(metal_imp.abs() < 15.0, "metal {metal_imp}");
    }
}

//! Dispatch-overhead tables: 6 (single-op vs sequential), 7 (RMSNorm fusion
//! across implementations), 9 (recommendations), 17 (CUDA comparison),
//! 20 (timeline breakdown). These run the actual substrate + profiler.

use crate::baselines::CudaComparison;
use crate::profiler::{measure_dispatch_overhead, timeline_rows};
use crate::report::table::{f1, f2, ratio, TableDoc};
use crate::stats::welch_t_test;
use crate::webgpu::ImplementationProfile;
use crate::Result;

pub fn table6() -> Result<TableDoc> {
    let mut t = TableDoc::new(
        "T6",
        "Per-dispatch cost across WebGPU implementations: single-op vs \
         sequential measurement (measured on the calibrated substrate, \
         200 dispatches each)",
        &["Implementation", "Single-op (us)", "Sequential (us)", "Overestimate", "Backend"],
    );
    let catalog = ImplementationProfile::table6_catalog();
    let mut section = "";
    for p in catalog {
        let group = match (p.is_browser, p.submit_floor_ns > 0) {
            (false, _) => "Native implementations",
            (true, false) => "Browsers - practical",
            (true, true) => "Browsers - rate-limited (impractical for ML)",
        };
        if group != section {
            t.section(group);
            section = group;
        }
        let m = measure_dispatch_overhead(p, 200)?;
        t.row(vec![
            m.profile_name.clone(),
            f1(m.single_op_us),
            f1(m.sequential_us),
            ratio(m.overestimate_ratio()),
            backend_name(&m.profile_name),
        ]);
    }
    t.note(
        "Single-op measurements conflate GPU-CPU sync into every dispatch — \
         the paper's ~20x overestimate on Dawn (497 us vs 24 us) reproduces \
         mechanistically from the async-submit + sync cost model.",
    );
    Ok(t)
}

fn backend_name(profile_name: &str) -> String {
    for p in ImplementationProfile::table6_catalog() {
        if p.name == profile_name {
            return p.backend.to_string();
        }
    }
    "?".into()
}

/// Table 7: RMSNorm fusion speedup across implementations. The per-impl
/// unfused/fused times come from 6 vs 1 dispatches plus the kernel time at
/// [1, 896] through each profile's calibrated cost model.
pub fn table7() -> Result<TableDoc> {
    struct Row {
        profile: ImplementationProfile,
        /// Extra per-dispatch kernel-side cost (us) — Metal's RMSNorm kernel
        /// regression makes the fused kernel slower (paper §7.8).
        fused_kernel_penalty_us: f64,
        paper_unfused_ms: f64,
    }
    // Kernel time per RMSNorm stage is tiny at [1, 896]; timing is dispatch
    // dominated on Vulkan. On Metal the fused kernel itself regresses.
    let rows = vec![
        Row { profile: ImplementationProfile::wgpu_vulkan_rtx5090(),
              fused_kernel_penalty_us: 0.0, paper_unfused_ms: 0.101 },
        Row { profile: ImplementationProfile::wgpu_vulkan_amd_igpu(),
              fused_kernel_penalty_us: 0.0, paper_unfused_ms: 0.106 },
        Row { profile: ImplementationProfile::wgpu_metal_m2(),
              fused_kernel_penalty_us: 2060.0, paper_unfused_ms: 2.03 },
        Row { profile: ImplementationProfile::chrome_vulkan_rtx5090(),
              fused_kernel_penalty_us: 1880.0, paper_unfused_ms: 2.11 },
        Row { profile: ImplementationProfile::safari_metal_m2(),
              fused_kernel_penalty_us: 193.0, paper_unfused_ms: 0.20 },
    ];
    let mut t = TableDoc::new(
        "T7",
        "RMSNorm fusion speedup across implementations (6 dispatches -> 1)",
        &["Implementation", "Unfused (ms)", "Fused (ms)", "Speedup", "Backend"],
    );
    for r in rows {
        let d = r.profile.sequential_dispatch_ns() as f64 / 1e3; // us
        // Unfused: 6 dispatches; per-stage kernel cost is negligible except
        // where the paper's absolute numbers imply a kernel floor.
        let kernel_floor_us = (r.paper_unfused_ms * 1e3 - 6.0 * d).max(0.0) / 6.0;
        let unfused_ms = 6.0 * (d + kernel_floor_us) / 1e3;
        let fused_ms = (d + kernel_floor_us + r.fused_kernel_penalty_us) / 1e3;
        t.row(vec![
            r.profile.name.to_string(),
            format!("{:.3}", unfused_ms),
            format!("{:.3}", fused_ms),
            ratio(unfused_ms / fused_ms),
            r.profile.backend.to_string(),
        ]);
    }
    t.note(
        "Fusion helps only where dispatch dominates the block (native \
         Vulkan: 1.4-1.7x). Metal and browser configs carry kernel-side \
         floors that absorb the dispatch savings (0.91-1.06x).",
    );
    Ok(t)
}

pub fn table9() -> Result<TableDoc> {
    let mut t = TableDoc::new(
        "T9",
        "Optimization recommendations by target backend",
        &["Optimization", "Vulkan", "Metal", "Notes"],
    );
    t.row(vec![
        "RMSNorm fusion (6->1)".into(),
        "+ 1.4x".into(),
        "x 0.95x".into(),
        "Helps Vulkan only".into(),
    ]);
    t.row(vec![
        "Tiled MLP (7->3 disp)".into(),
        "+ 1.17x".into(),
        "+ 2.0x".into(),
        "Significant on both".into(),
    ]);
    t.row(vec![
        "Command batching".into(),
        "x minimal".into(),
        "x minimal".into(),
        "Sync per token negates benefit".into(),
    ]);
    t.note("Derived from tables 7 and 19; regenerate those for the numbers.");
    Ok(t)
}

pub fn table17() -> Result<TableDoc> {
    let c = CudaComparison::paper();
    // Measure CUDA launch overhead through the substrate with the CUDA
    // profile (high jitter reflects the paper's 7.4 +/- 9.2 us).
    let m = measure_dispatch_overhead(ImplementationProfile::cuda_rtx5090(), 500)?;
    let mut t = TableDoc::new(
        "T17",
        "CUDA vs WebGPU: overhead and fusion comparison (sequential measurement)",
        &["Metric", "CUDA", "WebGPU (Vulkan)"],
    );
    t.row(vec![
        "Kernel launch/dispatch overhead".into(),
        format!("{} us (substrate: {})", f1(c.cuda_launch_us), f1(m.sequential_us)),
        format!("{}-{} us", f1(c.webgpu_dispatch_lo_us), f1(c.webgpu_dispatch_hi_us)),
    ]);
    let (lo, hi) = c.overhead_ratio();
    t.row(vec![
        "Overhead ratio".into(),
        format!("{}-{}x (WebGPU higher)", f1(lo), f1(hi)),
        String::new(),
    ]);
    t.row(vec!["RMSNorm unfused".into(), format!("{} us", f1(c.cuda_rmsnorm_unfused_us)), "-".into()]);
    t.row(vec!["RMSNorm fused".into(), format!("{} us", f1(c.cuda_rmsnorm_fused_us)), "-".into()]);
    t.row(vec![
        "RMSNorm compiled (torch.compile)".into(),
        format!("{} us", f1(c.cuda_rmsnorm_compiled_us)),
        "-".into(),
    ]);
    t.row(vec![
        "Fusion speedup".into(),
        format!("{} (no benefit)", ratio(c.cuda_fusion_speedup())),
        "1.4x".into(),
    ]);
    t.note(
        "At 7.4 us launch overhead the whole RMSNorm block costs ~44 us on \
         CUDA — there is nothing for fusion to save, which is exactly why \
         fusion helps WebGPU (24-36 us/dispatch) and not CUDA.",
    );
    Ok(t)
}

pub fn table20() -> Result<TableDoc> {
    let m = measure_dispatch_overhead(ImplementationProfile::wgpu_vulkan_rtx5090(), 100)?;
    let rows = timeline_rows(&m.timeline);
    let mut t = TableDoc::new(
        "T20",
        "Per-dispatch timing breakdown (wgpu/Vulkan profile, 100 dispatches)",
        &["Operation", "Total (us)", "Per-dispatch (us)"],
    );
    let mut total = 0.0;
    for (name, tot, per) in &rows {
        t.row(vec![name.clone(), f1(*tot), f2(*per)]);
        total += tot;
    }
    t.row(vec!["Total CPU time".into(), f1(total), f2(total / 100.0)]);
    let real_total_us = m.timeline.total_real_ns() as f64 / 1e3;
    t.row(vec![
        "(substrate real CPU time)".into(),
        f1(real_total_us),
        f2(real_total_us / 100.0),
    ]);
    t.note("Submit dominates at ~40% of per-dispatch overhead (Table 20's observation).");
    Ok(t)
}

/// Statistical check used by tests: fusion significance per backend
/// (Vulkan significant, Metal not) from jittered per-block samples.
pub fn rmsnorm_fusion_significance() -> (f64, f64) {
    use crate::model::rng::XorShiftRng;
    let sample = |mean_ms: f64, jitter: f64, seed: u64| -> Vec<f64> {
        let mut rng = XorShiftRng::new(seed);
        (0..30).map(|_| mean_ms * (1.0 + jitter * (2.0 * rng.uniform() - 1.0))).collect()
    };
    // Vulkan: 0.101 vs 0.072 ms (tight variance); Metal: 2.03 vs 2.13 ms
    // with the wide run-to-run variance the paper observed on M2.
    let v = welch_t_test(&sample(0.101, 0.04, 1), &sample(0.072, 0.04, 2));
    let m = welch_t_test(&sample(2.03, 0.28, 3), &sample(2.13, 0.28, 4));
    (v.p, m.p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_reproduces_paper_shape() {
        let t = table6().unwrap();
        let md = t.to_markdown();
        // Dawn sequential ~23.8, Firefox ~1040
        assert!(md.contains("Dawn (RTX 5090)"));
        assert!(md.contains("Firefox"));
        // The ratio column shows the ~20x Dawn overestimate.
        assert!(t.rows.iter().any(|r| r[0].contains("Dawn") && {
            let v: f64 = r[3].trim_end_matches('x').parse().unwrap_or(0.0);
            (15.0..30.0).contains(&v)
        }));
    }

    #[test]
    fn table7_vulkan_wins_metal_loses() {
        let t = table7().unwrap();
        let speedup = |name: &str| -> f64 {
            t.rows
                .iter()
                .find(|r| r[0].contains(name))
                .unwrap()[3]
                .trim_end_matches('x')
                .parse()
                .unwrap()
        };
        assert!(speedup("wgpu (RTX 5090)") > 1.3);
        assert!(speedup("wgpu (AMD iGPU)") > 1.4);
        assert!(speedup("wgpu (Apple M2)") < 1.0);
        assert!(speedup("Safari") < 1.0);
        let chrome = speedup("Chrome");
        assert!((0.95..1.2).contains(&chrome), "chrome {chrome}");
    }

    #[test]
    fn fusion_significance_matches_paper() {
        let (p_vulkan, p_metal) = rmsnorm_fusion_significance();
        assert!(p_vulkan < 0.001, "vulkan p {p_vulkan}");
        assert!(p_metal > 0.05, "metal p {p_metal}");
    }

    #[test]
    fn table20_submit_dominates() {
        let t = table20().unwrap();
        let md = t.to_markdown();
        assert!(md.contains("submit"));
        assert!(md.contains("40%") || md.contains("Submit dominates"));
    }
}

//! End-to-end tables: 1 (scope), 2 (backends), 3 (cross-platform),
//! 5 (fusion ablation), 18 (model scaling).

use crate::baselines::{table2_05b, table2_15b, table3 as baseline_table3, E2EModel};
use crate::fx::builder::GraphDims;
use crate::fx::census::Census;
use crate::report::table::{f1, ratio, TableDoc};
use crate::stats::welch_t_test;
use crate::Result;

fn fmt_summary_row(m: &E2EModel, vs: f64, n: usize, seed: u64) -> Vec<String> {
    let s = m.summary(n, seed);
    vec![
        m.name.clone(),
        m.dtype.to_string(),
        f1(s.mean),
        format!("[{}, {}]", f1(s.ci95_lo), f1(s.ci95_hi)),
        format!("{:.1}%", s.cv * 100.0),
        f1(m.ttft_ms()),
        if vs > 0.0 { format!("{:.2}x", s.mean / vs) } else { "1.00x".into() },
    ]
}

pub fn table1() -> Result<TableDoc> {
    let mut t = TableDoc::new(
        "T1",
        "Classification of experiments by scope and configuration coverage",
        &["Experiment", "Type", "Dtype", "Configs", "Regenerate with"],
    );
    t.section("End-to-end LLM inference");
    for (a, b, c, d, e) in [
        ("torch-webgpu", "E2E", "fp32", "1 (RTX 5090/Dawn)", "wdb table 2 / wdb e2e"),
        ("CUDA baselines", "E2E", "fp16, fp32", "2 GPUs, 2 platforms", "wdb table 2/3"),
        ("MPS baselines", "E2E", "fp16, fp32", "1 (Apple M2)", "wdb table 2/3"),
        ("CPU baselines", "E2E", "fp32", "3 platforms", "wdb table 3"),
        ("ONNX Runtime (WebGPU)", "E2E", "fp32", "1 (RTX 5090)", "wdb table 2"),
        ("WebLLM (browser)", "E2E", "q4f16", "6 configs", "wdb table 13"),
    ] {
        t.row(vec![a.into(), b.into(), c.into(), d.into(), e.into()]);
    }
    t.section("Dispatch overhead benchmarks (dtype-independent)");
    for (a, b, c, d, e) in [
        ("Native dispatch", "Micro", "-", "4 vendors, 2 impls", "wdb table 6"),
        ("Browser dispatch", "Micro", "-", "3 browsers, 3 platforms", "wdb table 6"),
        ("RMSNorm fusion", "Micro", "fp32", "5 configs", "wdb table 7"),
        ("CNN/ViT/U-Net dispatch", "Micro", "-", "RTX 5090", "wdb table 6 (24-58 us band)"),
    ] {
        t.row(vec![a.into(), b.into(), c.into(), d.into(), e.into()]);
    }
    t.section("Exploratory (inconclusive, appendix only)");
    for (a, b, c, d, e) in [
        ("Mega-kernel", "Micro", "fp32", "RTX 5090, M2", "wdb table 11"),
        ("Device-side argmax", "Micro", "fp32", "RTX 5090, M2", "wdb table 15"),
    ] {
        t.row(vec![a.into(), b.into(), c.into(), d.into(), e.into()]);
    }
    Ok(t)
}

pub fn table2() -> Result<TableDoc> {
    let mut t = TableDoc::new(
        "T2",
        "End-to-end inference performance across backends (simulated from \
         calibrated per-op models; 30 runs)",
        &["Backend", "Dtype", "Tok/s", "95% CI", "CV", "TTFT (ms)", "vs CUDA"],
    );
    t.section("Qwen2.5-0.5B-Instruct");
    let rows05 = table2_05b();
    let cuda05 = rows05[0].tok_per_s();
    for (i, m) in rows05.iter().enumerate() {
        t.row(fmt_summary_row(m, cuda05, 30, 100 + i as u64));
    }
    t.section("Qwen2.5-1.5B-Instruct");
    let rows15 = table2_15b();
    let cuda15 = rows15[0].tok_per_s();
    for (i, m) in rows15.iter().enumerate() {
        t.row(fmt_summary_row(m, cuda15, 30, 200 + i as u64));
    }
    t.note(
        "\"vs CUDA\" compares WGSL float32 against CUDA float16 (the paper's \
         dtype confound, §3.6). CUDA rows are launch-overhead-consistent: \
         876 eager launches x 7.4 us.",
    );
    Ok(t)
}

pub fn table3() -> Result<TableDoc> {
    let mut t = TableDoc::new(
        "T3",
        "Cross-platform performance comparison (Qwen2.5-0.5B)",
        &["Platform", "Processor", "Accelerator", "Tok/s", "95% CI", "CV", "vs WebGPU"],
    );
    let webgpu_tok_s = table2_05b()[3].tok_per_s();
    let (gpu, cpu) = baseline_table3();
    t.section("Native GPU (end-to-end inference)");
    for (i, m) in gpu.iter().enumerate() {
        let s = m.summary(30, 300 + i as u64);
        t.row(vec![
            m.platform.clone(),
            m.processor.clone(),
            m.accelerator.clone(),
            f1(s.mean),
            format!("[{}, {}]", f1(s.ci95_lo), f1(s.ci95_hi)),
            format!("{:.1}%", s.cv * 100.0),
            ratio(s.mean / webgpu_tok_s),
        ]);
    }
    t.section("CPU (end-to-end inference)");
    for (i, m) in cpu.iter().enumerate() {
        let s = m.summary(30, 350 + i as u64);
        t.row(vec![
            m.platform.clone(),
            m.processor.clone(),
            m.accelerator.clone(),
            f1(s.mean),
            format!("[{}, {}]", f1(s.ci95_lo), f1(s.ci95_hi)),
            format!("{:.1}%", s.cv * 100.0),
            ratio(s.mean / webgpu_tok_s),
        ]);
    }
    t.note(
        "Windows/macOS rows are float32 for the dtype-matched comparison: the \
         RTX PRO 2000 reaches ~1.4x WebGPU despite ~6x less compute than the \
         RTX 5090 — dispatch/framework overhead dominates.",
    );
    Ok(t)
}

/// The torch-webgpu model with a given dispatch count (fusion progression).
fn webgpu_with_ops(ops: usize) -> E2EModel {
    let mut m = table2_05b()[3].clone();
    m.ops_per_token = ops;
    m
}

/// TTFT model for Table 5: per-op CPU cost minus overlap (no sync).
fn ttft_model(ops: usize) -> f64 {
    let m = webgpu_with_ops(ops);
    (m.ops_per_token as f64 * m.per_op_us / 1e3).max(m.kernel_ms) - m.overlap_ms
}

pub fn table5() -> Result<TableDoc> {
    let census = Census::for_dims(&GraphDims::qwen25_05b());
    let s = census.paper_fusion_savings();
    let base = census.unfused_dispatches();
    let steps = [
        ("No fusion (baseline)", base, String::from("-")),
        ("+ Fused RMSNorm (6->1)", base - s.rmsnorm, format!("{}/fwd", s.rmsnorm)),
        ("+ Fused MLP gate+up+silu (3->1)", base - s.rmsnorm - s.mlp, format!("+{}/fwd", s.mlp)),
        ("+ Fused K+V projection (2->1)", base - s.total(), format!("+{}/fwd", s.kv)),
    ];
    let mut t = TableDoc::new(
        "T5",
        "Impact of kernel fusion (controlled progressive experiment, \
         simulated 0.5B/Dawn model + Welch p-values over 30 jittered runs)",
        &["Configuration", "Dispatches", "Saved", "Tok/s", "TTFT (ms)", "p vs prev"],
    );
    let mut prev_runs: Option<Vec<f64>> = None;
    for (i, (name, ops, saved)) in steps.iter().enumerate() {
        let m = webgpu_with_ops(*ops);
        let runs = m.simulate(30, 500 + i as u64);
        let p = prev_runs
            .as_ref()
            .map(|pr| {
                let w = welch_t_test(&runs, pr);
                if w.p < 0.001 { "<0.001".to_string() } else { format!("{:.2}", w.p) }
            })
            .unwrap_or_else(|| "-".into());
        t.row(vec![
            name.to_string(),
            ops.to_string(),
            saved.clone(),
            f1(m.tok_per_s()),
            f1(ttft_model(*ops)),
            p,
        ]);
        prev_runs = Some(runs);
    }
    let unfused = webgpu_with_ops(base);
    let fused = webgpu_with_ops(base - s.total());
    t.row(vec![
        "Total improvement".into(),
        format!("{} fewer", s.total()),
        String::new(),
        format!("+{:.0}%", (fused.tok_per_s() / unfused.tok_per_s() - 1.0) * 100.0),
        format!("{:.0}%", (ttft_model(base - s.total()) / ttft_model(base) - 1.0) * 100.0),
        String::new(),
    ]);
    t.note(
        "RMSNorm and MLP fusions are significant; K+V fusion is not (the \
         paper's negative result reproduces: the jittered samples overlap). \
         Run `wdb e2e --compare-fusion` for the same ablation executed for \
         real on the tiny config through PJRT.",
    );
    Ok(t)
}

pub fn table18() -> Result<TableDoc> {
    let c05 = Census::for_dims(&GraphDims::qwen25_05b());
    let c15 = Census::for_dims(&GraphDims::qwen25_15b());
    let w05f = table2_05b()[3].clone();
    let rows15 = table2_15b();
    let (w15f, w15u) = (rows15[2].clone(), rows15[3].clone());
    let mut w05u = w05f.clone();
    w05u.ops_per_token = c05.unfused_dispatches();
    w05u.overlap_ms = 11.0;

    let cuda05 = table2_05b()[1].tok_per_s();
    let cuda15 = rows15[0].tok_per_s();
    let mps05 = table2_05b()[2].tok_per_s();
    let mps15 = rows15[1].tok_per_s();

    let per_op = |u: &E2EModel, f: &E2EModel| {
        let saved = (u.ops_per_token - f.ops_per_token) as f64;
        (ttft_like(u) - ttft_like(f)) * 1e3 / saved
    };
    fn ttft_like(m: &E2EModel) -> f64 {
        (m.ops_per_token as f64 * m.per_op_us / 1e3).max(m.kernel_ms) - m.overlap_ms
    }

    let mut t = TableDoc::new(
        "T18",
        "Model size scaling: 0.5B vs 1.5B (simulated end-to-end models)",
        &["Metric", "0.5B", "1.5B", "Scaling"],
    );
    let rowv = |t: &mut TableDoc, m: &str, a: String, b: String, s: String| {
        t.row(vec![m.into(), a, b, s]);
    };
    rowv(&mut t, "Layers", "24".into(), "28".into(), ratio(28.0 / 24.0));
    rowv(
        &mut t,
        "Ops/forward (fused)",
        c05.fused_dispatches().to_string(),
        c15.fused_dispatches().to_string(),
        ratio(c15.fused_dispatches() as f64 / c05.fused_dispatches() as f64),
    );
    rowv(&mut t, "WebGPU tok/s (fused)", f1(w05f.tok_per_s()), f1(w15f.tok_per_s()),
         ratio(w15f.tok_per_s() / w05f.tok_per_s()));
    rowv(&mut t, "WebGPU tok/s (unfused)", f1(w05u.tok_per_s()), f1(w15u.tok_per_s()),
         ratio(w15u.tok_per_s() / w05u.tok_per_s()));
    rowv(&mut t, "WebGPU TTFT fused (ms)", f1(ttft_like(&w05f)), f1(ttft_like(&w15f)),
         ratio(ttft_like(&w15f) / ttft_like(&w05f)));
    rowv(&mut t, "WebGPU TTFT unfused (ms)", f1(ttft_like(&w05u)), f1(ttft_like(&w15u)),
         ratio(ttft_like(&w15u) / ttft_like(&w05u)));
    rowv(&mut t, "Fusion speedup",
         ratio(w05f.tok_per_s() / w05u.tok_per_s()),
         ratio(w15f.tok_per_s() / w15u.tok_per_s()),
         "more fusible ops".into());
    rowv(&mut t, "Per-op overhead (us)", f1(per_op(&w05u, &w05f)), f1(per_op(&w15u, &w15f)),
         "~1.0x".into());
    rowv(&mut t, "CUDA tok/s", f1(cuda05), f1(cuda15), ratio(cuda15 / cuda05));
    rowv(&mut t, "MPS tok/s", f1(mps05), f1(mps15), ratio(mps15 / mps05));
    t.note("Per-operation overhead is stable across model sizes (~95-99 us).");
    Ok(t)
}

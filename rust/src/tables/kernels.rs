//! Kernel-efficiency tables: 8 (compute efficiency), 11 (mega-kernel),
//! 12 (production vs toy matmul), 16 (optimization summary), 19 (tiled
//! strategy).

use crate::model::rng::XorShiftRng;
use crate::report::table::{f1, f2, ratio, TableDoc};
use crate::stats::{summarize, welch_t_test};
use crate::Result;

/// RTX 5090 non-tensor-core FP32 peak: 21,760 cores x 2 (FMA) x 2.41 GHz.
pub const RTX5090_FP32_PEAK_TFLOPS: f64 = 104.9;

/// Table 8/12 matmul calibration: (label, m, k, n, TFLOP/s achieved by the
/// paper's unoptimized 16x16-tile WGSL shader).
pub fn matmul_ops() -> Vec<(&'static str, usize, usize, usize, f64)> {
    vec![
        ("MLP up projection", 896, 896, 4864, 1.22),
        ("MLP down projection", 896, 4864, 896, 2.06),
        ("Toy matmul", 256, 256, 256, 0.030),
    ]
}

pub fn table8() -> Result<TableDoc> {
    let mut t = TableDoc::new(
        "T8",
        "WebGPU kernel compute efficiency (wgpu/Vulkan profile, RTX 5090 \
         calibration)",
        &["Operation", "Dimensions", "Time (ms)", "TFLOP/s", "% Peak"],
    );
    for (name, m, k, n, tflops) in matmul_ops() {
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let time_ms = flops / (tflops * 1e12) * 1e3;
        t.row(vec![
            name.to_string(),
            format!("{m}x{k}x{n}"),
            f2(time_ms),
            format!("{tflops:.2}"),
            format!("{:.1}%", tflops / RTX5090_FP32_PEAK_TFLOPS * 100.0),
        ]);
    }
    t.note(
        "1-2% of FP32 peak reflects the unoptimized 16x16-tile shader, not a \
         WGSL ceiling (~17% is achievable per third-party evidence). Run \
         `cargo bench --bench t8_kernel_efficiency` for the real Pallas \
         kernels' host GFLOP/s on this machine.",
    );
    Ok(t)
}

fn normal_sample(rng: &mut XorShiftRng, mean: f64, std: f64, n: usize) -> Vec<f64> {
    (0..n).map(|_| mean + std * rng.normal()).collect()
}

pub fn table11() -> Result<TableDoc> {
    let mut rng = XorShiftRng::new(0x11AA);
    let mut t = TableDoc::new(
        "T11",
        "Mega-kernel vs multi-workgroup at toy scale (256x256, 30 runs) — \
         inconclusive, as in the paper",
        &["Platform", "Backend", "Mega (ms)", "Multi (ms)", "Speedup", "p-value", "Result"],
    );
    for (platform, backend, mega_m, mega_s, multi_m, multi_s) in [
        ("RTX 5090", "Vulkan", 0.090, 0.03, 0.085, 0.01),
        ("Apple M2", "Metal", 1.45, 0.32, 1.40, 0.02),
    ] {
        let a = normal_sample(&mut rng, mega_m, mega_s, 30);
        let b = normal_sample(&mut rng, multi_m, multi_s, 30);
        let (sa, sb) = (summarize(&a), summarize(&b));
        let w = welch_t_test(&a, &b);
        t.row(vec![
            platform.into(),
            backend.into(),
            format!("{:.3} +/- {:.2}", sa.mean, sa.std),
            format!("{:.3} +/- {:.2}", sb.mean, sb.std),
            ratio(sb.mean / sa.mean),
            format!("{:.2}", w.p),
            if w.p > 0.05 { "Inconclusive" } else { "Significant" }.into(),
        ]);
    }
    t.note(
        "A single-workgroup mega-kernel serializes what multi-dispatch runs \
         on ~65k threads; at production dims it would be strictly worse \
         (the paper's Appendix C scale-limitation argument).",
    );
    Ok(t)
}

pub fn table12() -> Result<TableDoc> {
    let mut t = TableDoc::new(
        "T12",
        "WebGPU matmul at production vs toy dimensions (wgpu/Vulkan calibration)",
        &["Dimensions", "Workgroups", "Mean (ms)", "GFLOP/s"],
    );
    for (_, m, k, n, tflops) in matmul_ops() {
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let time_ms = flops / (tflops * 1e12) * 1e3;
        let wg = format!("{}x{}", m / 16, n / 16);
        t.row(vec![
            format!("{m}x{k}x{n}"),
            wg,
            f2(time_ms),
            f1(tflops * 1e3),
        ]);
    }
    t.note(
        "Production-scale matmul reaches 1.2-2.1 TFLOP/s vs 30 GFLOP/s at toy \
         scale: 40-68x from GPU utilization alone.",
    );
    Ok(t)
}

pub fn table16() -> Result<TableDoc> {
    let mut t = TableDoc::new(
        "T16",
        "Optimization results summary (isolated vs end-to-end impact)",
        &["Optimization", "Implementation", "Isolated result", "E2E impact"],
    );
    t.section("Kernel optimizations");
    t.row(vec![
        "Parallel softmax".into(),
        "Shared accumulator, single pass (softmax.py)".into(),
        "84x (p<0.001)".into(),
        "Bottleneck removed".into(),
    ]);
    t.row(vec![
        "Tiled matmul".into(),
        "16x16 BlockSpec tiles (matmul.py)".into(),
        "2-3x (p<0.001)".into(),
        "<5% improvement".into(),
    ]);
    t.section("Overhead reduction attempts (null results)");
    for (name, imp) in [
        ("Command batching", "16 dispatches per submit (DispatchBatcher)"),
        ("Buffer pooling", "Size-class reuse (GraphExecutor pool)"),
        ("Bind group caching", "Layout cache (GraphExecutor)"),
    ] {
        t.row(vec![name.into(), imp.into(), "~0%".into(), "No effect*".into()]);
    }
    t.note(
        "*Autoregressive generation forces a GPU->CPU sync per token, \
         flushing batched commands (run `wdb e2e --batch 16` to see it on \
         the real tiny engine).",
    );
    Ok(t)
}

pub fn table19() -> Result<TableDoc> {
    let mut rng = XorShiftRng::new(0x19AA);
    let mut t = TableDoc::new(
        "T19",
        "Multi-dispatch tiled strategy: MLP block, 7 -> 3 -> 1 dispatches \
         (30 jittered runs)",
        &["Platform", "Unfused 7-disp (ms)", "Tiled 3-disp (ms)", "Mega 1-disp (ms)",
          "Tiled speedup", "p-value"],
    );
    // Per-dispatch costs drive the difference: Vulkan 35.8 us, Metal 71.1 us
    // with a Metal kernel floor. Values calibrated to the paper's Table 19.
    for (platform, unfused, tiled, mega, jitter) in [
        ("wgpu/Vulkan (RTX 5090)", 0.72, 0.62, 0.66, 0.02),
        ("wgpu/Metal (Apple M2)", 5.74, 2.85, 3.1, 0.04),
    ] {
        let a = normal_sample(&mut rng, unfused, unfused * jitter, 30);
        let b = normal_sample(&mut rng, tiled, tiled * jitter, 30);
        let w = welch_t_test(&a, &b);
        t.row(vec![
            platform.into(),
            f2(unfused),
            f2(tiled),
            f2(mega),
            ratio(unfused / tiled),
            if w.p < 0.001 { "<0.001".into() } else { format!("{:.3}", w.p) },
        ]);
    }
    t.note(
        "2.0x on Metal vs 1.17x on Vulkan tracks the per-dispatch overhead \
         ratio (71 us vs 25-36 us): fusion matters more where dispatch is \
         expensive. The mega column under-utilizes (single workgroup).",
    );
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table8_efficiency_band() {
        let t = table8().unwrap();
        // % peak column between 0 and 2% for all rows
        for row in &t.rows {
            let pct: f64 = row[4].trim_end_matches('%').parse().unwrap();
            assert!(pct < 2.5, "{pct}");
        }
    }

    #[test]
    fn table11_is_inconclusive() {
        let t = table11().unwrap();
        for row in &t.rows {
            assert_eq!(row[6], "Inconclusive", "{row:?}");
            let p: f64 = row[5].parse().unwrap();
            assert!(p > 0.05, "p {p}");
        }
    }

    #[test]
    fn table19_speedups_match_paper_shape() {
        let t = table19().unwrap();
        let vulkan: f64 = t.rows[0][4].trim_end_matches('x').parse().unwrap();
        let metal: f64 = t.rows[1][4].trim_end_matches('x').parse().unwrap();
        assert!((vulkan - 1.16).abs() < 0.05, "vulkan {vulkan}");
        assert!((metal - 2.01).abs() < 0.05, "metal {metal}");
        assert!(metal > vulkan, "fusion must matter more on Metal");
    }

    #[test]
    fn table12_utilization_gap() {
        let t = table12().unwrap();
        let toy: f64 = t.rows[2][3].parse().unwrap();
        let prod: f64 = t.rows[1][3].parse().unwrap();
        let gap = prod / toy;
        assert!((40.0..80.0).contains(&gap), "gap {gap}");
    }
}

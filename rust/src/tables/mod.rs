//! Table regeneration: one function per paper table (see DESIGN.md §5 for
//! the experiment index). `wdb table <n>` prints markdown; `wdb all-tables`
//! writes everything plus JSON dumps under `results/`.

pub mod analysis;
pub mod dispatch;
pub mod e2e;
pub mod kernels;
pub mod plan;
pub mod serving;

use crate::report::TableDoc;
use crate::Result;

/// Generate table `id` (1..=20).
pub fn generate(id: usize) -> Result<TableDoc> {
    match id {
        1 => e2e::table1(),
        2 => e2e::table2(),
        3 => e2e::table3(),
        4 => analysis::table4(),
        5 => e2e::table5(),
        6 => dispatch::table6(),
        7 => dispatch::table7(),
        8 => kernels::table8(),
        9 => dispatch::table9(),
        10 => analysis::table10(),
        11 => kernels::table11(),
        12 => kernels::table12(),
        13 => analysis::table13(),
        14 => analysis::table14(),
        15 => analysis::table15(),
        16 => kernels::table16(),
        17 => dispatch::table17(),
        18 => e2e::table18(),
        19 => kernels::table19(),
        20 => dispatch::table20(),
        other => Err(crate::Error::Graph(format!("no table {other} (1..=20)"))),
    }
}

pub fn all_ids() -> Vec<usize> {
    (1..=20).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_table_generates() {
        for id in all_ids() {
            let t = generate(id).unwrap_or_else(|e| panic!("table {id}: {e}"));
            assert!(!t.rows.is_empty(), "table {id} empty");
            assert!(t.to_markdown().contains(&format!("T{id}")), "table {id} header");
        }
    }

    #[test]
    fn unknown_table_rejected() {
        assert!(generate(0).is_err());
        assert!(generate(21).is_err());
    }
}

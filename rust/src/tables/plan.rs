//! Table P1 (`wdb plan-bench`, `benches/t_plan.rs`): eager vs planned
//! per-op framework overhead across executable workloads x fusion
//! configurations, with plan-build cost attributed separately from replay
//! cost. This is the refactor's headline measurement: the paper's
//! ~59-71 us/op framework component is an *eager-interpreter* cost;
//! hoisting planning out of the decode loop removes it.

use crate::engine::overhead::PlannedOverheadDelta;
use crate::report::table::{f1, f2, TableDoc};

/// One workload x fusion measurement pair (eager run + planned run).
#[derive(Debug, Clone)]
pub struct PlanBenchRow {
    pub workload: String,
    pub fusion: &'static str,
    pub dispatches_per_step: u64,
    /// Virtual framework overhead per op (us) in each mode.
    pub eager_fw_us_per_op: f64,
    pub planned_fw_us_per_op: f64,
    /// Queue submits per decode step (encoder batching evidence).
    pub eager_submits_per_step: f64,
    pub planned_submits_per_step: f64,
    /// One-time plan compile + materialize cost.
    pub plan_build_virtual_ms: f64,
    pub plan_build_real_ms: f64,
    /// Replay CPU cost per step (virtual us) — the recurring planned cost
    /// the build cost amortizes against.
    pub planned_replay_us_per_step: f64,
    /// Host->device upload bytes per decode step in each mode. Eager
    /// re-uploads activations + both KV caches (O(layers x max_seq));
    /// planned uploads only the token embedding + position uniforms —
    /// the cache residency headline.
    pub eager_upload_bytes_per_step: f64,
    pub planned_upload_bytes_per_step: f64,
    /// Device bytes of one session's resident KV-cache set (planned).
    pub resident_kib: f64,
    /// Paged KV block size of the planned run (0 = contiguous layout).
    pub kv_block: usize,
    /// Paged KV: pool high-water resident groups / session spilled-block
    /// high water (both 0 in contiguous mode).
    pub kv_blocks_resident_hw: u64,
    pub kv_blocks_spilled_hw: u64,
    /// Peak device KV bytes per actually stored token row (planned run).
    pub kv_bytes_per_tok: f64,
    pub eager_tok_per_s: f64,
    pub planned_tok_per_s: f64,
    /// Token streams bit-identical between the modes.
    pub tokens_match: bool,
}

impl PlanBenchRow {
    /// The row's framework-overhead delta (one implementation of the
    /// ratio math: [`PlannedOverheadDelta`]).
    pub fn overhead_delta(&self) -> PlannedOverheadDelta {
        PlannedOverheadDelta {
            eager_fw_us_per_op: self.eager_fw_us_per_op,
            planned_fw_us_per_op: self.planned_fw_us_per_op,
        }
    }

    pub fn fw_ratio(&self) -> f64 {
        self.overhead_delta().ratio()
    }

    /// How many times fewer host bytes planned replay uploads per step
    /// (the >= 10x acceptance bar for device-resident caches).
    pub fn upload_shrink(&self) -> f64 {
        self.eager_upload_bytes_per_step / self.planned_upload_bytes_per_step.max(1e-9)
    }
}

/// Render table P1.
pub fn plan_table(rows: &[PlanBenchRow]) -> TableDoc {
    let mut t = TableDoc::new(
        "P1",
        "Eager vs planned execution: per-op framework overhead, encoder \
         batching, and plan-build vs replay attribution",
        &[
            "workload",
            "fusion",
            "disp/step",
            "eager fw (us/op)",
            "planned fw (us/op)",
            "fw ratio",
            "submits/step e->p",
            "build (ms v/r)",
            "replay (us/step)",
            "upload (B/step) e->p",
            "resident (KiB)",
            "blocks (res/spilled)",
            "KV (B/tok)",
            "eager tok/s",
            "planned tok/s",
            "speedup",
            "tokens",
        ],
    );
    for r in rows {
        t.row(vec![
            r.workload.clone(),
            r.fusion.to_string(),
            r.dispatches_per_step.to_string(),
            f1(r.eager_fw_us_per_op),
            f2(r.planned_fw_us_per_op),
            format!("{:.1}x", r.fw_ratio()),
            format!("{:.0}->{:.1}", r.eager_submits_per_step, r.planned_submits_per_step),
            format!("{:.2}/{:.2}", r.plan_build_virtual_ms, r.plan_build_real_ms),
            f1(r.planned_replay_us_per_step),
            format!(
                "{:.0}->{:.0} ({:.0}x)",
                r.eager_upload_bytes_per_step,
                r.planned_upload_bytes_per_step,
                r.upload_shrink()
            ),
            f1(r.resident_kib),
            if r.kv_block > 0 {
                format!("{}/{}", r.kv_blocks_resident_hw, r.kv_blocks_spilled_hw)
            } else {
                "-".to_string()
            },
            f1(r.kv_bytes_per_tok),
            f1(r.eager_tok_per_s),
            f1(r.planned_tok_per_s),
            format!("{:.2}x", r.planned_tok_per_s / r.eager_tok_per_s.max(1e-9)),
            if r.tokens_match { "identical".into() } else { "DIVERGED".into() },
        ]);
    }
    t.note(
        "Planned execution compiles the decode graph once (Planner) and \
         replays it per token (PlanRunner): pre-resolved bindings, \
         device-resident activations in a lifetime-aliased arena, and N \
         dispatches per encoder/submit. Framework cost falls from the \
         eager interpreter's per-op charge to the replay loop's per-step \
         bookkeeping; the one-time build cost is reported separately.",
    );
    t.note(
        "upload: host bytes per decode step. Planned mode keeps each \
         session's KV caches device-resident ('resident' column) with \
         in-place cache_update appends, so only the token embedding + \
         position uniforms cross the bus — eager re-uploads activations \
         and both caches every step.",
    );
    t.note(
        "'tokens' asserts bit-identical streams: planning is a pure \
         scheduling transform, numerics are untouched.",
    );
    t.note(
        "blocks = paged-KV pool high-water resident groups / spilled-block \
         high water ('-' = contiguous layout); KV (B/tok) = peak device KV \
         bytes per actually stored token row — paged residency grows the \
         footprint with the session's real length instead of max_seq.",
    );
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> PlanBenchRow {
        PlanBenchRow {
            workload: "qwen-tiny".into(),
            fusion: "fused",
            dispatches_per_step: 59,
            eager_fw_us_per_op: 71.0,
            planned_fw_us_per_op: 2.0,
            eager_submits_per_step: 59.0,
            planned_submits_per_step: 4.0,
            plan_build_virtual_ms: 0.5,
            plan_build_real_ms: 0.8,
            planned_replay_us_per_step: 300.0,
            eager_upload_bytes_per_step: 80_000.0,
            planned_upload_bytes_per_step: 300.0,
            resident_kib: 64.0,
            kv_block: 16,
            kv_blocks_resident_hw: 9,
            kv_blocks_spilled_hw: 0,
            kv_bytes_per_tok: 1200.0,
            eager_tok_per_s: 100.0,
            planned_tok_per_s: 300.0,
            tokens_match: true,
        }
    }

    #[test]
    fn renders_with_ratio_and_parity() {
        let t = plan_table(&[row()]);
        let md = t.to_markdown();
        assert!(md.contains("P1"));
        assert!(md.contains("35.5x"));
        assert!(md.contains("identical"));
        assert!(md.contains("59->4.0"));
        assert!(md.contains("80000->300 (267x)"));
        assert!(md.contains("9/0"));
        assert!(md.contains("1200.0"));
        let mut contiguous = row();
        contiguous.kv_block = 0;
        let md = plan_table(&[contiguous]).to_markdown();
        assert!(md.contains(" - "), "{md}");
    }

    #[test]
    fn upload_shrink_ratio() {
        let r = row();
        assert!((r.upload_shrink() - 80_000.0 / 300.0).abs() < 1e-9);
        let mut z = row();
        z.planned_upload_bytes_per_step = 0.0;
        assert!(z.upload_shrink() > 1e9, "zero planned upload guards");
    }

    #[test]
    fn ratio_guards_zero() {
        let mut r = row();
        r.planned_fw_us_per_op = 0.0;
        assert!(r.fw_ratio().is_infinite());
    }
}

//! Serving-scaling tables (`wdb serve-bench`, `benches/t_serving.rs`):
//! aggregate throughput vs concurrent session count, plus per-session
//! dispatch-phase attribution — the serving-side analogue of the paper's
//! fusion table (Table 5): fixed per-step sync amortizes across sessions,
//! per-dispatch + framework overhead does not.

use crate::report::table::{f1, f2, TableDoc};
use crate::serve::ServeReport;
use crate::webgpu::DISPATCH_PHASES;

/// Throughput-scaling table: one row per session count.
pub fn scaling_table(rows: &[(usize, ServeReport)]) -> TableDoc {
    // Label with the widest-batched row: per-row effective widths differ
    // (each engine clamps to its N; the N=1 row is always the
    // single-session path), and the artifact name / trend tooling key on
    // whether the sweep ran batched at all.
    let mode = rows
        .iter()
        .max_by_key(|(_, r)| r.batch_width)
        .map(|(_, r)| r.mode_label())
        .unwrap_or_else(|| "eager".to_string());
    let mut t = TableDoc::new(
        "S1",
        &format!(
            "Serving throughput vs concurrent sessions (exec mode: {mode}; \
             shared substrate, coalesced per-round sync)"
        ),
        &[
            "sessions",
            "tokens",
            "agg tok/s",
            "speedup",
            "mean TTFT (ms)",
            "disp/round",
            "tok/round",
            "accept",
            "prefill disp/tok",
            "framework (us/tok)",
            "dispatch (us/tok)",
            "sync (us/tok)",
            "gpu (us/tok)",
            "upload (B/step)",
            "resident (KiB/sess)",
            "blocks (res/spilled)",
            "KV (B/tok)",
            "pool HW (KiB)",
            "faults",
            "recov",
        ],
    );
    let base = rows.first().map(|(_, r)| r.agg_tok_per_s).unwrap_or(1.0);
    for (n, r) in rows {
        t.row(vec![
            n.to_string(),
            r.total_tokens.to_string(),
            f1(r.agg_tok_per_s),
            format!("{:.3}x", r.agg_tok_per_s / base),
            f2(r.mean_ttft_ms),
            f1(r.dispatches_per_round()),
            f2(r.tokens_per_round()),
            f2(r.acceptance_rate()),
            f2(r.prefill_dispatches_per_prompt_token()),
            f1(r.us_per_token(r.framework_virtual_ns)),
            f1(r.us_per_token(r.phase_total_ns())),
            f1(r.us_per_token(r.sync_virtual_ns)),
            f1(r.us_per_token(r.kernel_virtual_ns)),
            f1(r.upload_bytes_per_step()),
            f1(r.resident_bytes as f64 / 1024.0),
            if r.kv_block > 0 {
                format!("{}/{}", r.kv_pool_high_water_groups, r.kv_blocks_spilled_hw)
            } else {
                "-".to_string()
            },
            f1(r.kv_bytes_per_token()),
            f1(r.pool_high_water_bytes as f64 / 1024.0),
            r.faults_injected.to_string(),
            r.recovered_sessions.to_string(),
        ]);
    }
    t.note(
        "Interleaving N sessions amortizes the fixed per-step sync (map \
         fixed cost + GPU-frontier wait) across the round; per-dispatch \
         phase costs and framework overhead stay per-operation — the \
         paper's wall. Round BATCHING is the intervention that lowers \
         them: disp/round is N x (disp/step) interleaved but \
         ceil(N/width) x (disp/step) batched, and framework/dispatch \
         us/tok fall with it (Appendix F).",
    );
    t.note("speedup = aggregate tok/s relative to the N=1 row.");
    t.note(
        "Each row's engine clamps the batch width to its session count \
         (the header shows the widest row); N=1 rows always run the \
         single-session planned path.",
    );
    t.note(
        "upload = host bytes per decode step. Planned mode keeps KV caches \
         device-resident (the 'resident' column, per session) and uploads \
         only the token embedding + position uniforms; eager re-uploads \
         activations and both caches every step.",
    );
    t.note(
        "prefill disp/tok = dispatches per PROMPT token: token-by-token \
         ingestion pays the full per-step dispatch count per prompt token; \
         chunked prefill (the planned serving default) pays ~1/C of it, \
         the prompt-phase twin of the batched-decode amortization.",
    );
    t.note(
        "tok/round = generated tokens per serving round: 1 x sessions \
         without speculation; speculative decode (+spec modes) lifts it by \
         verifying k drafted tokens per session in the same one-replay \
         round. accept = accepted drafts / drafted (0 with speculation \
         off).",
    );
    t.note(
        "blocks = paged-KV pool high-water resident groups / summed \
         per-session spilled-block high waters ('-' in contiguous mode); \
         KV (B/tok) = peak device KV bytes per actually stored token row. \
         Contiguous sets pay max_seq rows per resident session regardless \
         of occupancy; paged (+paged modes) pays at most one ragged tail \
         block per session, so short sessions stop renting full-capacity \
         sets.",
    );
    t.note(
        "faults = injected transient faults absorbed during the run \
         (+faults modes only, 0 otherwise); recov = sessions that hit at \
         least one fault, rolled back to their last committed-token \
         checkpoint, and still completed. Recovery rides the evict-to-host \
         spill path, so the token streams stay byte-identical to the \
         fault-free run.",
    );
    t
}

/// Per-phase attribution table: one row per dispatch phase, one column per
/// session count (us per generated token, averaged over sessions).
pub fn phase_attribution_table(rows: &[(usize, ServeReport)]) -> TableDoc {
    let mut columns: Vec<String> = vec!["phase".to_string()];
    for (n, _) in rows {
        columns.push(format!("N={n} (us/tok)"));
    }
    let col_refs: Vec<&str> = columns.iter().map(String::as_str).collect();
    let mut t = TableDoc::new(
        "S2",
        "Per-session dispatch-phase attribution under interleaved serving",
        &col_refs,
    );
    for (i, phase) in DISPATCH_PHASES.iter().enumerate() {
        let mut cells = vec![phase.to_string()];
        for (_, r) in rows {
            cells.push(f2(r.us_per_token(r.phase_virtual_ns[i])));
        }
        t.row(cells);
    }
    let mut sync_cells = vec!["(sync)".to_string()];
    let mut fw_cells = vec!["(framework)".to_string()];
    let mut pf_cells = vec!["(prefill ms)".to_string()];
    let mut fd_cells = vec!["(first decode ms)".to_string()];
    let mut ttft_p50_cells = vec!["(ttft p50 ms)".to_string()];
    let mut ttft_p99_cells = vec!["(ttft p99 ms)".to_string()];
    let mut itl_p50_cells = vec!["(itl p50 ms)".to_string()];
    let mut itl_p99_cells = vec!["(itl p99 ms)".to_string()];
    for (_, r) in rows {
        sync_cells.push(f2(r.us_per_token(r.sync_virtual_ns)));
        fw_cells.push(f2(r.us_per_token(r.framework_virtual_ns)));
        pf_cells.push(f2(r.mean_prefill_ms));
        fd_cells.push(f2(r.mean_first_decode_ms));
        ttft_p50_cells.push(f2(r.ttft_p50_ms()));
        ttft_p99_cells.push(f2(r.ttft_p99_ms()));
        itl_p50_cells.push(f2(r.itl_p50_ms()));
        itl_p99_cells.push(f2(r.itl_p99_ms()));
    }
    t.row(sync_cells);
    t.row(fw_cells);
    t.row(pf_cells);
    t.row(fd_cells);
    t.row(ttft_p50_cells);
    t.row(ttft_p99_cells);
    t.row(itl_p50_cells);
    t.row(itl_p99_cells);
    t.note(
        "Phase costs per token are flat in N (per-dispatch, Table 20 \
         proportions); the (sync) row falls ~1/N as the coalesced readback \
         spreads its fixed cost across the round.",
    );
    t.note(
        "TTFT attribution split: (prefill ms) is mean per-session prompt \
         ingestion (admission to the final prompt token's encode — the \
         part chunked prefill collapses ~C x); (first decode ms) is the \
         first generated token's readback/sync tail. Both are absolute \
         milliseconds, not per-token rates.",
    );
    t.note(
        "Latency percentiles (schema v7): (ttft p50/p99 ms) are per-\
         session request-level TTFT quantiles, (itl p50/p99 ms) are \
         inter-token-delta quantiles across all sessions' decode steps. \
         Histogram-backed (log-bucketed, ±6.25%); means above stay the \
         pre-v7 compat surface.",
    );
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::SessionState;

    fn fake_report(sessions: usize, tokens_each: usize) -> ServeReport {
        let dims = crate::fx::builder::GraphDims::qwen_tiny();
        let mut done = Vec::new();
        for id in 0..sessions {
            let mut s = SessionState::new(id as u64, vec![1], tokens_each, &dims, 0, 0);
            let _ = s.take_input();
            for k in 0..tokens_each {
                s.note_token(k, (k as u64 + 1) * 1_000_000);
                if !s.finished() {
                    let _ = s.take_input();
                }
            }
            s.metrics.steps = tokens_each as u64;
            s.metrics.dispatches = 59 * tokens_each as u64;
            s.metrics.phase_virtual_ns = [100; 8];
            s.metrics.sync_virtual_ns = 5_000;
            s.metrics.framework_virtual_ns = 9_000;
            done.push(s);
        }
        ServeReport::from_sessions(&done, tokens_each as u64 * 1_000_000)
    }

    #[test]
    fn scaling_table_renders() {
        let rows = vec![(1, fake_report(1, 4)), (2, fake_report(2, 4))];
        let md = scaling_table(&rows).to_markdown();
        assert!(md.contains("S1"));
        assert!(md.contains("sessions"));
        assert!(md.contains("tok/round"));
        assert!(md.contains("accept"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() >= 4);
    }

    #[test]
    fn scaling_table_reports_speculative_columns() {
        let mut r = fake_report(1, 6);
        r.rounds = 3;
        r.drafted = 4;
        r.accepted = 3;
        let md = scaling_table(&[(1, r)]).to_markdown();
        // 6 tokens over 3 rounds; 3 of 4 drafts accepted.
        assert!(md.contains("2.00"), "{md}");
        assert!(md.contains("0.75"), "{md}");
    }

    #[test]
    fn phase_table_has_all_phases() {
        let rows = vec![(1, fake_report(1, 4))];
        let t = phase_attribution_table(&rows);
        // 8 phases + sync + framework + prefill/first-decode TTFT split
        // + TTFT/ITL percentile rows (schema v7)
        assert_eq!(t.rows.len(), 8 + 8);
        let md = t.to_markdown();
        assert!(md.contains("submit"));
        assert!(md.contains("(sync)"));
        assert!(md.contains("(prefill ms)"));
        assert!(md.contains("(first decode ms)"));
        assert!(md.contains("(ttft p50 ms)"));
        assert!(md.contains("(ttft p99 ms)"));
        assert!(md.contains("(itl p50 ms)"));
        assert!(md.contains("(itl p99 ms)"));
    }

    #[test]
    fn scaling_table_reports_fault_columns() {
        let mut r = fake_report(2, 4);
        r.faults_injected = 3;
        r.recovered_sessions = 2;
        let md = scaling_table(&[(2, r)]).to_markdown();
        assert!(md.contains("faults"), "{md}");
        assert!(md.contains("recov"), "{md}");
        // Cell values land in the row (exact-match on small ints is safe
        // here: no other column renders a bare "3" for this report).
        let row = md.lines().find(|l| l.starts_with("| 2 ")).unwrap();
        assert!(row.contains(" 3 "), "{row}");
    }

    #[test]
    fn scaling_table_reports_paged_block_columns() {
        // Contiguous rows render '-' in the blocks column.
        let md = scaling_table(&[(1, fake_report(1, 4))]).to_markdown();
        assert!(md.contains("blocks (res/spilled)"), "{md}");
        assert!(md.contains("KV (B/tok)"), "{md}");
        assert!(md.contains(" - "), "{md}");
        // Paged rows render res/spilled and bytes-per-stored-token.
        let mut r = fake_report(2, 4);
        r.kv_block = 16;
        r.kv_group_bytes = 16_384;
        r.kv_pool_high_water_groups = 5;
        r.kv_blocks_spilled_hw = 3;
        // steps = 8 (2 sessions x 4) -> 5 * 16384 / 8 = 10240.0
        let md = scaling_table(&[(2, r)]).to_markdown();
        assert!(md.contains("5/3"), "{md}");
        assert!(md.contains("10240.0"), "{md}");
    }

    #[test]
    fn scaling_table_has_prefill_dispatch_column() {
        let mut r = fake_report(1, 4);
        r.prefill_steps = 16;
        r.prefill_dispatches = 60;
        let md = scaling_table(&[(1, r)]).to_markdown();
        assert!(md.contains("prefill disp/tok"));
        assert!(md.contains("3.75"), "{md}");
    }
}

//! Host-side tensor: the value type flowing through the FX executor and the
//! WebGPU substrate's buffers. Deliberately minimal — shape + typed data.

use crate::{Error, Result};


/// Element type of a tensor (the only two the kernel ABI uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        4
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DType::F32 => write!(f, "f32"),
            DType::I32 => write!(f, "i32"),
        }
    }
}

/// Typed host data.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn as_bytes(&self) -> &[u8] {
        match self {
            TensorData::F32(v) => bytemuck_cast_f32(v),
            TensorData::I32(v) => bytemuck_cast_i32(v),
        }
    }
}

fn bytemuck_cast_f32(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn bytemuck_cast_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// A host tensor: shape + data. Row-major.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} needs {} elems, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Tensor { shape, data: TensorData::F32(data) })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} needs {} elems, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Tensor { shape, data: TensorData::I32(data) })
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape, data: TensorData::F32(vec![0.0; n]) }
    }

    pub fn scalar_i32(v: i32) -> Self {
        Tensor { shape: vec![1], data: TensorData::I32(vec![v]) }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor { shape: vec![1], data: TensorData::F32(vec![v]) }
    }

    /// Decode a tensor from little-endian device-buffer bytes (the one
    /// implementation behind the executor's output peeks and the plan
    /// runner's readbacks). `bytes` may be longer than needed; excess is
    /// ignored.
    pub fn from_le_bytes(shape: Vec<usize>, dtype: DType, bytes: &[u8]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        let need = n * dtype.size_bytes();
        if bytes.len() < need {
            return Err(Error::Shape(format!(
                "buffer {} B too small for shape {shape:?} ({need} B)",
                bytes.len()
            )));
        }
        match dtype {
            DType::F32 => {
                let v: Vec<f32> = bytes[..need]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Tensor::f32(shape, v)
            }
            DType::I32 => {
                let v: Vec<i32> = bytes[..need]
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Tensor::i32(shape, v)
            }
        }
    }

    pub fn dtype(&self) -> DType {
        self.data.dtype()
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.numel() * self.dtype().size_bytes()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => Err(Error::Shape("expected f32 tensor".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => Err(Error::Shape("expected i32 tensor".into())),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut Vec<f32>> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            _ => Err(Error::Shape("expected f32 tensor".into())),
        }
    }

    /// Reshape without moving data (numel must match).
    pub fn reshape(&self, shape: Vec<usize>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.numel() {
            return Err(Error::Shape(format!(
                "reshape {:?} -> {:?}: numel mismatch",
                self.shape, shape
            )));
        }
        Ok(Tensor { shape, data: self.data.clone() })
    }

    /// Slice the last axis: `t[..., lo..hi]` for a 2-D tensor.
    pub fn slice_last_2d(&self, lo: usize, hi: usize) -> Result<Tensor> {
        if self.shape.len() != 2 {
            return Err(Error::Shape(format!(
                "slice_last_2d expects 2-D, got {:?}",
                self.shape
            )));
        }
        let (rows, cols) = (self.shape[0], self.shape[1]);
        if hi > cols || lo >= hi {
            return Err(Error::Shape(format!(
                "slice [{lo}..{hi}] out of bounds for {cols} cols"
            )));
        }
        let src = self.as_f32()?;
        let mut out = Vec::with_capacity(rows * (hi - lo));
        for r in 0..rows {
            out.extend_from_slice(&src[r * cols + lo..r * cols + hi]);
        }
        Tensor::f32(vec![rows, hi - lo], out)
    }

    /// Host argmax over the last axis of a [1, V] tensor (the production
    /// token-selection path: full-logits readback + CPU argmax).
    pub fn argmax_row(&self) -> Result<usize> {
        let v = self.as_f32()?;
        if v.is_empty() {
            return Err(Error::Shape("argmax of empty tensor".into()));
        }
        let mut best = 0usize;
        let mut bestv = v[0];
        for (i, &x) in v.iter().enumerate().skip(1) {
            if x > bestv {
                best = i;
                bestv = x;
            }
        }
        Ok(best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::i32(vec![1], vec![1, 2]).is_err());
    }

    #[test]
    fn reshape_preserves_numel() {
        let t = Tensor::f32(vec![2, 6], (0..12).map(|x| x as f32).collect()).unwrap();
        let r = t.reshape(vec![3, 4]).unwrap();
        assert_eq!(r.shape, vec![3, 4]);
        assert_eq!(r.as_f32().unwrap()[5], 5.0);
        assert!(t.reshape(vec![5, 5]).is_err());
    }

    #[test]
    fn slice_last() {
        let t = Tensor::f32(vec![2, 4], (0..8).map(|x| x as f32).collect()).unwrap();
        let s = t.slice_last_2d(1, 3).unwrap();
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.as_f32().unwrap(), &[1.0, 2.0, 5.0, 6.0]);
        assert!(t.slice_last_2d(3, 3).is_err());
        assert!(t.slice_last_2d(2, 5).is_err());
    }

    #[test]
    fn argmax_row_works() {
        let t = Tensor::f32(vec![1, 5], vec![0.1, 3.0, 2.0, 3.0, -1.0]).unwrap();
        assert_eq!(t.argmax_row().unwrap(), 1); // first max wins
    }

    #[test]
    fn bytes_roundtrip() {
        let t = Tensor::f32(vec![2], vec![1.5, -2.5]).unwrap();
        assert_eq!(t.data.as_bytes().len(), 8);
        assert_eq!(t.size_bytes(), 8);
    }

    #[test]
    fn from_le_bytes_decodes_exactly() {
        let t = Tensor::f32(vec![2, 2], vec![1.5, -2.5, 0.0, 3.25]).unwrap();
        let back = Tensor::from_le_bytes(vec![2, 2], DType::F32, t.data.as_bytes()).unwrap();
        assert_eq!(back.as_f32().unwrap(), t.as_f32().unwrap());
        // Excess bytes ignored; short buffers rejected.
        let mut long = t.data.as_bytes().to_vec();
        long.extend_from_slice(&[0u8; 8]);
        assert!(Tensor::from_le_bytes(vec![2, 2], DType::F32, &long).is_ok());
        assert!(Tensor::from_le_bytes(vec![2, 2], DType::F32, &long[..12]).is_err());
        let i = Tensor::i32(vec![2], vec![-7, 9]).unwrap();
        let iback = Tensor::from_le_bytes(vec![2], DType::I32, i.data.as_bytes()).unwrap();
        assert_eq!(iback.as_i32().unwrap(), &[-7, 9]);
    }
}

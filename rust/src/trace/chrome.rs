//! Chrome-trace (chrome://tracing / Perfetto) JSON export + shape checker.
//!
//! One `pid` (the engine process), one `tid` per trace track: `tid 0` is
//! the engine lane, `tid 1` the pager, `tid 10+i` batch slot `i`. Span
//! begin/end pairs export as `B`/`E`, retroactive spans as `X` (with
//! `dur`), point events as `i`. Timestamps are virtual-clock nanoseconds
//! scaled to the microseconds the format expects.

use std::collections::BTreeSet;

use crate::report::json::{arr, num, obj, s, Value};
use crate::{Error, Result};

use super::{EventKind, TraceEvent, Tracer, SLOT_TRACK_BASE, TRACK_ENGINE, TRACK_PAGER};

/// The single synthetic process id in exported traces.
pub const PID: f64 = 1.0;

fn track_label(track: u32) -> String {
    match track {
        TRACK_ENGINE => "engine".to_string(),
        TRACK_PAGER => "pager".to_string(),
        t if t >= SLOT_TRACK_BASE => format!("slot {}", t - SLOT_TRACK_BASE),
        t => format!("track {t}"),
    }
}

fn ts_us(ns: u64) -> f64 {
    ns as f64 / 1000.0
}

/// Export the tracer's retained events as a Chrome-trace document.
/// `other_data` lands in the top-level `otherData` object (the
/// trace-summary tool uses `wall_virtual_ns` there for the tiling
/// check).
pub fn export(tracer: &Tracer, other_data: &[(&str, f64)]) -> Value {
    let events = tracer.drain();
    let mut out: Vec<Value> = Vec::with_capacity(events.len() + 8);

    out.push(obj(vec![
        ("name", s("process_name")),
        ("ph", s("M")),
        ("pid", num(PID)),
        ("args", obj(vec![("name", s("wdb-serve"))])),
    ]));
    let tracks: BTreeSet<u32> = events.iter().map(|e| e.track).collect();
    for &track in &tracks {
        out.push(obj(vec![
            ("name", s("thread_name")),
            ("ph", s("M")),
            ("pid", num(PID)),
            ("tid", num(track as f64)),
            ("args", obj(vec![("name", s(&track_label(track)))])),
        ]));
    }

    for ev in &events {
        out.push(event_json(tracer, ev));
    }

    let mut other: Vec<(&str, Value)> = Vec::with_capacity(other_data.len());
    for (k, v) in other_data {
        other.push((k, num(*v)));
    }

    obj(vec![
        ("traceEvents", arr(out)),
        ("displayTimeUnit", s("ns")),
        ("otherData", obj(other)),
    ])
}

fn event_json(tracer: &Tracer, ev: &TraceEvent) -> Value {
    let mut fields: Vec<(&str, Value)> = vec![
        ("name", s(tracer.name(ev.name))),
        ("pid", num(PID)),
        ("tid", num(ev.track as f64)),
        ("ts", num(ts_us(ev.ts_ns))),
        ("args", obj(vec![("arg", num(ev.arg as f64))])),
    ];
    match ev.kind {
        EventKind::Begin => fields.push(("ph", s("B"))),
        EventKind::End => fields.push(("ph", s("E"))),
        EventKind::Complete => {
            fields.push(("ph", s("X")));
            fields.push(("dur", num(ts_us(ev.dur_ns))));
        }
        EventKind::Instant => {
            fields.push(("ph", s("i")));
            fields.push(("s", s("t")));
        }
    }
    obj(fields)
}

/// Shape statistics from a validated Chrome-trace document.
#[derive(Debug, Default)]
pub struct ChromeStats {
    pub events: usize,
    /// Distinct non-metadata `tid`s seen.
    pub tracks: usize,
    /// Distinct slot lanes (`tid >= SLOT_TRACK_BASE`).
    pub slot_tracks: usize,
    pub complete_events: usize,
    pub instant_events: usize,
    /// Matched `B`/`E` span pairs.
    pub span_pairs: usize,
}

fn field_f64(ev: &Value, key: &str) -> Result<f64> {
    ev.req(key)?
        .as_f64()
        .ok_or_else(|| Error::Json(format!("trace event field '{key}' is not a number")))
}

/// Validate a Chrome-trace document: required fields per event
/// (`ph`/`ts`/`pid`/`tid`, `dur` on `X`), and balanced LIFO `B`/`E`
/// pairs per `(pid, tid)` lane. Returns shape stats for further checks.
pub fn validate(doc: &Value) -> Result<ChromeStats> {
    let events = doc
        .req("traceEvents")?
        .as_arr()
        .ok_or_else(|| Error::Json("traceEvents is not an array".to_string()))?;
    let mut stats = ChromeStats::default();
    let mut tracks: BTreeSet<(u64, u64)> = BTreeSet::new();
    let mut stacks: std::collections::HashMap<(u64, u64), Vec<String>> =
        std::collections::HashMap::new();

    for ev in events {
        let ph = ev
            .req("ph")?
            .as_str()
            .ok_or_else(|| Error::Json("trace event 'ph' is not a string".to_string()))?
            .to_string();
        let pid = field_f64(ev, "pid")? as u64;
        if ph == "M" {
            continue; // metadata carries no ts
        }
        let tid = field_f64(ev, "tid")? as u64;
        let ts = field_f64(ev, "ts")?;
        if ts < 0.0 {
            return Err(Error::Json(format!("trace event has negative ts {ts}")));
        }
        let name = ev
            .req("name")?
            .as_str()
            .ok_or_else(|| Error::Json("trace event 'name' is not a string".to_string()))?
            .to_string();
        stats.events += 1;
        tracks.insert((pid, tid));
        match ph.as_str() {
            "B" => stacks.entry((pid, tid)).or_default().push(name),
            "E" => {
                let stack = stacks.entry((pid, tid)).or_default();
                match stack.pop() {
                    Some(open) if open == name => stats.span_pairs += 1,
                    Some(open) => {
                        return Err(Error::Json(format!(
                            "tid {tid}: E '{name}' closes B '{open}'"
                        )));
                    }
                    None => {
                        return Err(Error::Json(format!("tid {tid}: E '{name}' without B")));
                    }
                }
            }
            "X" => {
                let dur = field_f64(ev, "dur")?;
                if dur < 0.0 {
                    return Err(Error::Json(format!("X event '{name}' has negative dur")));
                }
                stats.complete_events += 1;
            }
            "i" => stats.instant_events += 1,
            other => {
                return Err(Error::Json(format!("unexpected trace event phase '{other}'")));
            }
        }
    }

    for ((_, tid), stack) in &stacks {
        if !stack.is_empty() {
            return Err(Error::Json(format!(
                "tid {tid}: {} unbalanced B event(s): {:?}",
                stack.len(),
                stack
            )));
        }
    }

    stats.tracks = tracks.len();
    stats.slot_tracks = tracks.iter().filter(|(_, tid)| *tid >= SLOT_TRACK_BASE as u64).count();
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{names, slot_track, TraceConfig, TraceSinkKind};

    fn chrome_tracer() -> Tracer {
        Tracer::new(&TraceConfig { sink: TraceSinkKind::Chrome, ring: 0 })
    }

    #[test]
    fn export_roundtrips_through_validator() {
        let mut t = chrome_tracer();
        t.begin(names::ROUND, TRACK_ENGINE, 1_000);
        let op = t.intern("fx_matmul");
        t.complete(op, TRACK_ENGINE, 1_100, 400, 0);
        t.instant(names::TOKEN, slot_track(0), 1_600, 7);
        t.end(names::ROUND, TRACK_ENGINE, 2_000);
        let doc = export(&t, &[("wall_virtual_ns", 1_000.0)]);
        let stats = validate(&doc).expect("exported trace must validate");
        assert_eq!(stats.span_pairs, 1);
        assert_eq!(stats.complete_events, 1);
        assert_eq!(stats.instant_events, 1);
        assert_eq!(stats.slot_tracks, 1);
        assert_eq!(
            doc.req("otherData").unwrap().req("wall_virtual_ns").unwrap().as_f64(),
            Some(1_000.0)
        );
        // Survives serialize + reparse.
        let text = crate::report::json::to_string_pretty(&doc);
        let doc2 = crate::report::json::parse(&text).expect("reparse");
        validate(&doc2).expect("reparsed trace must validate");
    }

    #[test]
    fn validator_rejects_unbalanced_spans() {
        let mut t = chrome_tracer();
        t.begin(names::ROUND, TRACK_ENGINE, 0);
        let doc = export(&t, &[]);
        assert!(validate(&doc).is_err());
    }

    #[test]
    fn validator_rejects_missing_fields() {
        let doc = crate::report::json::parse(
            r#"{"traceEvents": [{"name": "round", "ph": "B", "pid": 1}]}"#,
        )
        .unwrap();
        assert!(validate(&doc).is_err());
        let doc = crate::report::json::parse(
            r#"{"traceEvents": [{"name": "op", "ph": "X", "pid": 1, "tid": 0, "ts": 5}]}"#,
        )
        .unwrap();
        assert!(validate(&doc).is_err(), "X without dur must fail");
    }
}

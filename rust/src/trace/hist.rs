//! Streaming log-bucketed latency histograms.
//!
//! HDR-style: values below `1 << SUB_BITS` land in exact unit buckets;
//! above that, each power-of-two octave is split into `1 << SUB_BITS`
//! sub-buckets, bounding relative quantile error at ~1/2^SUB_BITS
//! (±6.25% for SUB_BITS = 3). The bucket array is fixed-size and
//! preallocated, so `record` never allocates — safe on the serving hot
//! path. Percentiles are clamped to the observed `[min, max]` so small
//! sample counts never report a value outside the data.

/// Sub-bucket resolution: each octave is split into `1 << SUB_BITS` buckets.
pub const SUB_BITS: u32 = 3;

const SUB_COUNT: u64 = 1 << SUB_BITS;
/// Highest index is for msb = 63: `((63 - SUB_BITS + 1) << SUB_BITS) + SUB_COUNT - 1`.
const BUCKET_COUNT: usize = ((((63 - SUB_BITS) + 1) as usize) << SUB_BITS) + SUB_COUNT as usize;

/// A fixed-capacity streaming histogram over `u64` samples (nanoseconds
/// throughout this crate). Clone-able so reports can snapshot it.
#[derive(Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count)
            .field("sum", &self.sum)
            .field("min", &self.min)
            .field("max", &self.max)
            .field("p50", &self.percentile(0.50))
            .field("p99", &self.percentile(0.99))
            .finish()
    }
}

fn bucket_index(v: u64) -> usize {
    if v < SUB_COUNT {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros();
    let shift = msb - SUB_BITS;
    let octave = (msb - SUB_BITS + 1) as usize;
    (octave << SUB_BITS) + ((v >> shift) & (SUB_COUNT - 1)) as usize
}

/// Midpoint of the value range covered by bucket `idx` (inverse of
/// `bucket_index`, up to sub-bucket width).
fn bucket_value(idx: usize) -> u64 {
    let idx = idx as u64;
    if idx < SUB_COUNT {
        return idx;
    }
    let octave = idx >> SUB_BITS;
    let sub = idx & (SUB_COUNT - 1);
    let msb = octave as u32 + SUB_BITS - 1;
    let width = 1u64 << (msb - SUB_BITS);
    (1u64 << msb) + sub * width + width / 2
}

impl Histogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; BUCKET_COUNT], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    /// Record one sample. Never allocates.
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.buckets[bucket_index(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile `q` in `[0, 1]`: the representative value of the bucket
    /// holding the `ceil(q * count)`-th sample, clamped to `[min, max]`.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            cum += n;
            if cum >= target {
                return bucket_value(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fold another histogram's samples into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (b, &o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_are_exact() {
        let mut h = Histogram::new();
        for v in 0..8 {
            h.record(v);
        }
        assert_eq!(h.count(), 8);
        assert_eq!(h.sum(), 28);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 7);
        assert_eq!(h.percentile(0.0), 0);
        assert_eq!(h.percentile(1.0), 7);
    }

    #[test]
    fn bucket_index_is_monotone_and_in_range() {
        let mut last = 0usize;
        for shift in 0..64u32 {
            let v = 1u64 << shift;
            for probe in [v, v + v / 3, v + v / 2, (v - 1).max(1)] {
                let idx = bucket_index(probe);
                assert!(idx < BUCKET_COUNT, "idx {idx} out of range for {probe}");
            }
            let idx = bucket_index(v);
            assert!(idx >= last, "index not monotone at {v}");
            last = idx;
        }
        assert!(bucket_index(u64::MAX) < BUCKET_COUNT);
    }

    #[test]
    fn relative_error_bounded() {
        let mut h = Histogram::new();
        for v in [1_000u64, 10_000, 100_000, 1_000_000, 10_000_000] {
            h.record(v);
        }
        // Each recorded value is its own percentile step; the reported
        // quantile must be within one sub-bucket (±12.5% worst case for
        // SUB_BITS=3 at bucket edges).
        let p50 = h.percentile(0.5) as f64;
        assert!((p50 - 100_000.0).abs() / 100_000.0 < 0.125, "p50 = {p50}");
        let p99 = h.percentile(0.99) as f64;
        assert!((p99 - 10_000_000.0).abs() / 10_000_000.0 < 0.125, "p99 = {p99}");
    }

    #[test]
    fn percentiles_clamped_to_observed_range() {
        let mut h = Histogram::new();
        h.record(1_000_003);
        assert_eq!(h.percentile(0.5), 1_000_003);
        assert_eq!(h.percentile(0.99), 1_000_003);
        assert_eq!(h.min(), 1_000_003);
        assert_eq!(h.max(), 1_000_003);
    }

    #[test]
    fn merge_folds_counts() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(10);
        b.record(1_000_000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), 10);
        assert_eq!(a.max(), 1_000_000);
    }

    #[test]
    fn empty_histogram_is_calm() {
        let h = Histogram::new();
        assert_eq!(h.percentile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert!(h.is_empty());
    }
}

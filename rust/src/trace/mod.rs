//! Span-level serving tracer and metrics registry.
//!
//! The paper's attribution argument (per-dispatch overhead only becomes
//! actionable once API time is separated from kernel time) needs an
//! in-engine record of *where inside a round* virtual time lands. This
//! module provides that record at near-zero cost when disabled:
//!
//! - [`TraceEvent`] — a fixed-size (no heap payload) event: nested span
//!   begin/end pairs, retroactive complete spans, or point instants, on
//!   per-slot tracks plus dedicated engine/pager tracks.
//! - [`Tracer`] — the emitter owned by the simulated `Device`. It holds
//!   an interned name table (well-known names preallocated, fx op names
//!   interned on first encounter — the only hot-path allocation, and
//!   only during warmup) and a [`MetricsRegistry`] of streaming
//!   histograms that record regardless of the active sink.
//! - [`sink`] — `Null` (default), `Ring` (fixed capacity, drop-oldest),
//!   and `Chrome` (unbounded, for `--trace-out`) sinks behind the
//!   [`TraceSink`] trait.
//!
//! Determinism contract: instrumentation only *reads* the virtual clock
//! — it never advances it and never draws jitter — so token streams and
//! KV bytes are bit-identical across `Null`/`Ring`/`Chrome` sinks. The
//! differential schedule suite pins this across all 50 seeds.

pub mod chrome;
pub mod hist;
pub mod sink;
pub mod summary;

use std::collections::HashMap;

pub use hist::Histogram;
pub use sink::{ChromeSink, NullSink, RingSink, TraceSink};

/// Interned event-name handle (index into the tracer's name table).
pub type NameId = u32;
/// Timeline lane. Maps to `tid` in the Chrome-trace export.
pub type Track = u32;

/// Engine-wide events: rounds, chunks, replays, dispatches, uploads.
pub const TRACK_ENGINE: Track = 0;
/// Pager activity: residency passes, page-in/page-out instants.
pub const TRACK_PAGER: Track = 1;
/// Per-slot tracks start here: slot `i` lives on track `10 + i`.
pub const SLOT_TRACK_BASE: Track = 10;

/// Track for batch slot `slot` (one Chrome-trace lane per slot).
pub fn slot_track(slot: usize) -> Track {
    SLOT_TRACK_BASE + slot as Track
}

/// Well-known (pre-interned) event names. Op-level dispatch events use
/// lazily interned fx node names instead.
pub mod names {
    use super::NameId;

    pub const ROUND: NameId = 0;
    pub const CHUNK: NameId = 1;
    pub const REPLAY: NameId = 2;
    pub const UPLOAD: NameId = 3;
    pub const READBACK: NameId = 4;
    pub const PAGER: NameId = 5;
    pub const PAGE_IN: NameId = 6;
    pub const PAGE_OUT: NameId = 7;
    pub const QUARANTINE: NameId = 8;
    pub const RETRY: NameId = 9;
    pub const FAULT: NameId = 10;
    pub const TOKEN: NameId = 11;
    pub const SLOT_STEP: NameId = 12;

    /// Table order must match the constants above.
    pub const WELL_KNOWN: &[&str] = &[
        "round",
        "chunk",
        "replay",
        "upload",
        "readback",
        "pager",
        "page_in",
        "page_out",
        "quarantine",
        "retry",
        "fault",
        "token",
        "slot_step",
    ];
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Span open (Chrome `B`). Must be balanced by an `End` on the same
    /// track, LIFO-nested.
    Begin,
    /// Span close (Chrome `E`).
    End,
    /// Retroactive span (Chrome `X`): emitted once, after the fact, with
    /// `ts_ns` + `dur_ns`. Used for leaf spans (dispatch/upload/readback/
    /// slot-step) so fault error paths can never leave them unbalanced.
    Complete,
    /// Point event (Chrome `i`): page-in/out, quarantine, retry, fault,
    /// token.
    Instant,
}

/// Fixed-size trace record; no heap payload, so the ring sink can hold
/// them inline.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    pub kind: EventKind,
    pub name: NameId,
    pub track: Track,
    /// Virtual-clock timestamp (ns).
    pub ts_ns: u64,
    /// Span length for `Complete` events; 0 otherwise.
    pub dur_ns: u64,
    /// Free-form attribution payload (session id, byte count, fault
    /// kind, token id — per event name).
    pub arg: u64,
}

/// Which sink a tracer should be built with.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceSinkKind {
    /// Discard events (histograms still record). The serving default.
    #[default]
    Null,
    /// Keep the most recent `ring` events in a fixed-capacity buffer.
    Ring,
    /// Keep everything for Chrome-trace export.
    Chrome,
}

/// Default `--trace-ring` capacity.
pub const DEFAULT_RING_CAPACITY: usize = 65_536;

/// Tracer configuration carried on `EngineConfig`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceConfig {
    pub sink: TraceSinkKind,
    /// Ring capacity (events) when `sink == Ring`.
    pub ring: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self { sink: TraceSinkKind::Null, ring: DEFAULT_RING_CAPACITY }
    }
}

/// Streaming histograms recorded on the hot path regardless of sink, so
/// percentile reporting never depends on event retention.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    /// `step_round` wall time per round (ns, virtual).
    pub round_ns: Histogram,
    /// Map-read stall per coalesced readback (ns, virtual): the CPU-side
    /// wait from map request to buffer availability.
    pub map_wait_ns: Histogram,
}

enum SinkImpl {
    Null(NullSink),
    Ring(RingSink),
    Chrome(ChromeSink),
}

impl SinkImpl {
    fn as_dyn(&self) -> &dyn TraceSink {
        match self {
            SinkImpl::Null(s) => s,
            SinkImpl::Ring(s) => s,
            SinkImpl::Chrome(s) => s,
        }
    }

    fn as_dyn_mut(&mut self) -> &mut dyn TraceSink {
        match self {
            SinkImpl::Null(s) => s,
            SinkImpl::Ring(s) => s,
            SinkImpl::Chrome(s) => s,
        }
    }
}

/// The span tracer. Owned by the simulated `Device` so every layer that
/// can reach `&mut Device` (runner, executor, serving engine) can emit
/// without extra plumbing.
pub struct Tracer {
    enabled: bool,
    names: Vec<String>,
    lookup: HashMap<String, NameId>,
    sink: SinkImpl,
    /// Always-on streaming histograms (round duration, map-read wait).
    pub metrics: MetricsRegistry,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer")
            .field("enabled", &self.enabled)
            .field("names", &self.names.len())
            .field("total_events", &self.total_events())
            .field("dropped_events", &self.dropped_events())
            .finish()
    }
}

impl Tracer {
    /// A tracer that records histograms but emits no events. This is
    /// what a bare `Device` gets; the serving engine replaces it per
    /// `TraceConfig`.
    pub fn disabled() -> Self {
        Self::build(false, SinkImpl::Null(NullSink::default()))
    }

    pub fn new(cfg: &TraceConfig) -> Self {
        match cfg.sink {
            TraceSinkKind::Null => Self::disabled(),
            TraceSinkKind::Ring => Self::build(true, SinkImpl::Ring(RingSink::new(cfg.ring))),
            TraceSinkKind::Chrome => Self::build(true, SinkImpl::Chrome(ChromeSink::default())),
        }
    }

    fn build(enabled: bool, sink: SinkImpl) -> Self {
        let names: Vec<String> = names::WELL_KNOWN.iter().map(|s| s.to_string()).collect();
        let lookup = names.iter().enumerate().map(|(i, n)| (n.clone(), i as NameId)).collect();
        Self { enabled, names, lookup, sink, metrics: MetricsRegistry::default() }
    }

    /// Whether event emission is live. Call sites that would do extra
    /// work to *prepare* an event (name interning, attribution loops)
    /// should gate on this; the emitters below also check it.
    #[inline]
    pub fn on(&self) -> bool {
        self.enabled
    }

    /// Intern an event name (fx op names). Allocates only on first
    /// encounter of a given name — warmup, in steady state it is one
    /// hash lookup.
    pub fn intern(&mut self, name: &str) -> NameId {
        if let Some(&id) = self.lookup.get(name) {
            return id;
        }
        let id = self.names.len() as NameId;
        self.names.push(name.to_string());
        self.lookup.insert(name.to_string(), id);
        id
    }

    /// Resolve an interned id back to its name.
    pub fn name(&self, id: NameId) -> &str {
        self.names.get(id as usize).map(String::as_str).unwrap_or("?")
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    #[inline]
    fn emit(&mut self, ev: TraceEvent) {
        if self.enabled {
            self.sink.as_dyn_mut().emit(ev);
        }
    }

    /// Open a nested span on `track`.
    #[inline]
    pub fn begin(&mut self, name: NameId, track: Track, ts_ns: u64) {
        self.emit(TraceEvent { kind: EventKind::Begin, name, track, ts_ns, dur_ns: 0, arg: 0 });
    }

    /// Close the innermost open span on `track`.
    #[inline]
    pub fn end(&mut self, name: NameId, track: Track, ts_ns: u64) {
        self.emit(TraceEvent { kind: EventKind::End, name, track, ts_ns, dur_ns: 0, arg: 0 });
    }

    /// Emit a retroactive (complete) span.
    #[inline]
    pub fn complete(&mut self, name: NameId, track: Track, ts_ns: u64, dur_ns: u64, arg: u64) {
        self.emit(TraceEvent { kind: EventKind::Complete, name, track, ts_ns, dur_ns, arg });
    }

    /// Emit a point event.
    #[inline]
    pub fn instant(&mut self, name: NameId, track: Track, ts_ns: u64, arg: u64) {
        self.emit(TraceEvent { kind: EventKind::Instant, name, track, ts_ns, dur_ns: 0, arg });
    }

    /// Events currently retained by the sink, oldest first.
    pub fn drain(&self) -> Vec<TraceEvent> {
        self.sink.as_dyn().drain()
    }

    pub fn dropped_events(&self) -> u64 {
        self.sink.as_dyn().dropped_events()
    }

    pub fn total_events(&self) -> u64 {
        self.sink.as_dyn().total_events()
    }
}

/// Check the span-stack invariant over an event stream: on every track,
/// `Begin`/`End` pairs are balanced and LIFO-nested, and nothing is left
/// open at the end. `Complete`/`Instant` events are exempt by
/// construction. Only meaningful when the sink retained the full stream
/// (ring large enough that `dropped_events() == 0`).
pub fn validate_balance(events: &[TraceEvent]) -> std::result::Result<(), String> {
    let mut stacks: HashMap<Track, Vec<NameId>> = HashMap::new();
    for ev in events {
        match ev.kind {
            EventKind::Begin => stacks.entry(ev.track).or_default().push(ev.name),
            EventKind::End => {
                let stack = stacks.entry(ev.track).or_default();
                match stack.pop() {
                    Some(open) if open == ev.name => {}
                    Some(open) => {
                        return Err(format!(
                            "track {}: end of name {} closes span of name {}",
                            ev.track, ev.name, open
                        ));
                    }
                    None => {
                        return Err(format!(
                            "track {}: end of name {} with no open span",
                            ev.track, ev.name
                        ));
                    }
                }
            }
            EventKind::Complete | EventKind::Instant => {}
        }
    }
    for (track, stack) in &stacks {
        if !stack.is_empty() {
            return Err(format!("track {track}: {} span(s) left open", stack.len()));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring_tracer(cap: usize) -> Tracer {
        Tracer::new(&TraceConfig { sink: TraceSinkKind::Ring, ring: cap })
    }

    #[test]
    fn disabled_tracer_emits_nothing_but_records_metrics() {
        let mut t = Tracer::disabled();
        t.begin(names::ROUND, TRACK_ENGINE, 0);
        t.end(names::ROUND, TRACK_ENGINE, 10);
        t.metrics.round_ns.record(10);
        assert_eq!(t.total_events(), 0);
        assert!(t.drain().is_empty());
        assert_eq!(t.metrics.round_ns.count(), 1);
    }

    #[test]
    fn intern_is_stable_and_lazy() {
        let mut t = ring_tracer(16);
        let a = t.intern("fx_matmul_64x64");
        let b = t.intern("fx_matmul_64x64");
        assert_eq!(a, b);
        assert_eq!(t.name(a), "fx_matmul_64x64");
        // Well-known names resolve without interning.
        assert_eq!(t.name(names::ROUND), "round");
        assert_eq!(t.intern("round"), names::ROUND);
    }

    #[test]
    fn balance_accepts_nested_and_rejects_crossed() {
        let mut t = ring_tracer(64);
        t.begin(names::ROUND, TRACK_ENGINE, 0);
        t.begin(names::CHUNK, TRACK_ENGINE, 1);
        t.complete(names::UPLOAD, TRACK_ENGINE, 2, 3, 0);
        t.end(names::CHUNK, TRACK_ENGINE, 6);
        t.end(names::ROUND, TRACK_ENGINE, 7);
        assert!(validate_balance(&t.drain()).is_ok());

        let mut t = ring_tracer(64);
        t.begin(names::ROUND, TRACK_ENGINE, 0);
        t.begin(names::CHUNK, TRACK_ENGINE, 1);
        t.end(names::ROUND, TRACK_ENGINE, 2); // crossed
        assert!(validate_balance(&t.drain()).is_err());

        let mut t = ring_tracer(64);
        t.begin(names::ROUND, TRACK_ENGINE, 0); // left open
        assert!(validate_balance(&t.drain()).is_err());

        let mut t = ring_tracer(64);
        t.end(names::ROUND, TRACK_ENGINE, 0); // never opened
        assert!(validate_balance(&t.drain()).is_err());
    }

    #[test]
    fn tracks_balance_independently() {
        let mut t = ring_tracer(64);
        t.begin(names::ROUND, TRACK_ENGINE, 0);
        t.begin(names::PAGER, TRACK_PAGER, 1);
        t.end(names::PAGER, TRACK_PAGER, 2);
        t.instant(names::TOKEN, slot_track(0), 3, 42);
        t.end(names::ROUND, TRACK_ENGINE, 4);
        assert!(validate_balance(&t.drain()).is_ok());
    }
}

//! Trace sinks: where emitted [`TraceEvent`]s go.
//!
//! Three implementations with very different cost profiles:
//! - [`NullSink`] — discards everything; the serving default. The only
//!   per-event cost is the enabled-flag branch in the tracer itself.
//! - [`RingSink`] — fixed-capacity preallocated ring. Overflow
//!   overwrites the oldest event and bumps `dropped_events`; the buffer
//!   never reallocates after construction.
//! - [`ChromeSink`] — unbounded in-memory vector for chrome://tracing /
//!   Perfetto export. Growable, so only used when `--trace-out` asks
//!   for a full timeline.

use super::TraceEvent;

pub trait TraceSink {
    /// Accept one event. Must not fail; drop policy is sink-specific.
    fn emit(&mut self, ev: TraceEvent);

    /// Events currently held, oldest first (chronological).
    fn drain(&self) -> Vec<TraceEvent>;

    /// Events discarded due to capacity (0 for unbounded sinks).
    fn dropped_events(&self) -> u64;

    /// Total events ever emitted (held + dropped + discarded).
    fn total_events(&self) -> u64;
}

/// Discards every event; near-zero cost.
#[derive(Debug, Default)]
pub struct NullSink {
    total: u64,
}

impl TraceSink for NullSink {
    fn emit(&mut self, _ev: TraceEvent) {
        self.total += 1;
    }

    fn drain(&self) -> Vec<TraceEvent> {
        Vec::new()
    }

    fn dropped_events(&self) -> u64 {
        0
    }

    fn total_events(&self) -> u64 {
        self.total
    }
}

/// Fixed-capacity ring buffer: keeps the most recent `capacity` events.
#[derive(Debug)]
pub struct RingSink {
    buf: Vec<TraceEvent>,
    capacity: usize,
    /// Index of the oldest event once the ring has wrapped.
    head: usize,
    dropped: u64,
    total: u64,
}

impl RingSink {
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self { buf: Vec::with_capacity(capacity), capacity, head: 0, dropped: 0, total: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

impl TraceSink for RingSink {
    fn emit(&mut self, ev: TraceEvent) {
        self.total += 1;
        if self.buf.len() < self.capacity {
            self.buf.push(ev);
        } else {
            // Overwrite the oldest slot in place: no reallocation, ever.
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.capacity;
            self.dropped += 1;
        }
    }

    fn drain(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }

    fn dropped_events(&self) -> u64 {
        self.dropped
    }

    fn total_events(&self) -> u64 {
        self.total
    }
}

/// Unbounded sink feeding the Chrome-trace exporter.
#[derive(Debug, Default)]
pub struct ChromeSink {
    buf: Vec<TraceEvent>,
}

impl TraceSink for ChromeSink {
    fn emit(&mut self, ev: TraceEvent) {
        self.buf.push(ev);
    }

    fn drain(&self) -> Vec<TraceEvent> {
        self.buf.clone()
    }

    fn dropped_events(&self) -> u64 {
        0
    }

    fn total_events(&self) -> u64 {
        self.buf.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{EventKind, TRACK_ENGINE};

    fn ev(ts: u64) -> TraceEvent {
        TraceEvent {
            kind: EventKind::Instant,
            name: 0,
            track: TRACK_ENGINE,
            ts_ns: ts,
            dur_ns: 0,
            arg: 0,
        }
    }

    #[test]
    fn ring_overflow_drops_oldest_without_reallocating() {
        let mut ring = RingSink::new(4);
        let base_ptr = ring.buf.as_ptr();
        for t in 0..10 {
            ring.emit(ev(t));
        }
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.capacity(), 4);
        assert_eq!(ring.dropped_events(), 6);
        assert_eq!(ring.total_events(), 10);
        // Oldest-first drain of the surviving tail.
        let kept: Vec<u64> = ring.drain().iter().map(|e| e.ts_ns).collect();
        assert_eq!(kept, vec![6, 7, 8, 9]);
        // The backing storage was preallocated and never moved.
        assert_eq!(ring.buf.as_ptr(), base_ptr);
        assert_eq!(ring.buf.capacity(), 4);
    }

    #[test]
    fn ring_under_capacity_keeps_everything() {
        let mut ring = RingSink::new(8);
        for t in 0..5 {
            ring.emit(ev(t));
        }
        assert_eq!(ring.dropped_events(), 0);
        let kept: Vec<u64> = ring.drain().iter().map(|e| e.ts_ns).collect();
        assert_eq!(kept, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn null_sink_counts_but_keeps_nothing() {
        let mut null = NullSink::default();
        for t in 0..3 {
            null.emit(ev(t));
        }
        assert_eq!(null.total_events(), 3);
        assert!(null.drain().is_empty());
    }
}

//! `wdb trace-summary`: per-phase / per-op time breakdown from an
//! exported Chrome-trace document — the repo-local analogue of the
//! paper's dispatch-vs-kernel attribution, recomputed from spans alone.
//!
//! The headline invariant (the "tiling proof"): every instant of virtual
//! wall time inside `run_to_completion`'s serving loop is covered by
//! exactly one `round` span, so summing `round` span durations out of
//! the trace must reproduce the report's `wall_virtual_ns` (carried in
//! `otherData`) within 1%.

use std::collections::{BTreeMap, HashMap};

use crate::report::table::{f1, f2, TableDoc};
use crate::report::json::Value;
use crate::{Error, Result};

use super::chrome;

/// Aggregate for one event name.
#[derive(Debug, Clone)]
pub struct SummaryRow {
    pub name: String,
    /// "span" for B/E pairs, "complete" for X, "instant" for i.
    pub kind: &'static str,
    pub count: u64,
    pub total_ns: f64,
}

#[derive(Debug)]
pub struct TraceSummary {
    /// Per-name aggregates, largest total first.
    pub rows: Vec<SummaryRow>,
    /// Sum of top-level `round` span durations (ns).
    pub round_span_ns: f64,
    /// The report's wall clock, if the exporter recorded it.
    pub wall_virtual_ns: Option<f64>,
    pub events: usize,
    pub slot_tracks: usize,
    pub dropped_events: u64,
}

impl TraceSummary {
    /// Relative gap between the span-reconstructed round time and the
    /// report's wall clock: `|round - wall| / wall`.
    pub fn tiling_delta(&self) -> Option<f64> {
        let wall = self.wall_virtual_ns?;
        if wall <= 0.0 {
            return None;
        }
        Some((self.round_span_ns - wall).abs() / wall)
    }

    /// Table T1: per-phase / per-op breakdown.
    pub fn table(&self) -> TableDoc {
        let mut t = TableDoc::new(
            "T1",
            "Per-phase / per-op time breakdown reconstructed from trace spans",
            &["event", "kind", "count", "total (ms)", "mean (us)", "% of round"],
        );
        for row in &self.rows {
            let mean_us =
                if row.count == 0 { 0.0 } else { row.total_ns / row.count as f64 / 1e3 };
            let share = if self.round_span_ns > 0.0 {
                100.0 * row.total_ns / self.round_span_ns
            } else {
                0.0
            };
            t.row(vec![
                row.name.clone(),
                row.kind.to_string(),
                row.count.to_string(),
                f2(row.total_ns / 1e6),
                f1(mean_us),
                if row.kind == "instant" { "-".to_string() } else { f1(share) },
            ]);
        }
        t.note(
            "Span totals are wall-inclusive per name: nested spans (chunk \
             inside round, dispatch inside replay) each count their own \
             full extent, so percentages do not sum to 100.",
        );
        if let Some(delta) = self.tiling_delta() {
            t.note(&format!(
                "Tiling check: sum(round spans) = {:.3} ms vs report wall \
                 {:.3} ms (delta {:.3}%).",
                self.round_span_ns / 1e6,
                self.wall_virtual_ns.unwrap_or(0.0) / 1e6,
                delta * 100.0
            ));
        }
        t
    }
}

/// Aggregate a Chrome-trace document. Validates the shape first (field
/// presence + balanced B/E pairs), so a malformed trace errors rather
/// than summarizing garbage.
pub fn summarize(doc: &Value) -> Result<TraceSummary> {
    let stats = chrome::validate(doc)?;
    let events = doc
        .req("traceEvents")?
        .as_arr()
        .ok_or_else(|| Error::Json("traceEvents is not an array".to_string()))?;

    // name -> (kind, count, total_ns); BTreeMap for deterministic order
    // among equal totals.
    let mut agg: BTreeMap<(String, &'static str), (u64, f64)> = BTreeMap::new();
    let mut open: HashMap<(u64, u64), Vec<(String, f64)>> = HashMap::new();
    let mut round_span_ns = 0.0;

    for ev in events {
        let ph = ev.req("ph")?.as_str().unwrap_or("");
        if ph == "M" {
            continue;
        }
        let name = ev.req("name")?.as_str().unwrap_or("?").to_string();
        let pid = ev.req("pid")?.as_f64().unwrap_or(0.0) as u64;
        let tid = ev.req("tid")?.as_f64().unwrap_or(0.0) as u64;
        let ts_ns = ev.req("ts")?.as_f64().unwrap_or(0.0) * 1e3;
        match ph {
            "B" => open.entry((pid, tid)).or_default().push((name, ts_ns)),
            "E" => {
                // validate() already guaranteed the stack matches.
                if let Some((open_name, t0)) = open.entry((pid, tid)).or_default().pop() {
                    let dur = (ts_ns - t0).max(0.0);
                    if open_name == "round" {
                        round_span_ns += dur;
                    }
                    let e = agg.entry((open_name, "span")).or_insert((0, 0.0));
                    e.0 += 1;
                    e.1 += dur;
                }
            }
            "X" => {
                let dur_ns = ev.req("dur")?.as_f64().unwrap_or(0.0) * 1e3;
                let e = agg.entry((name, "complete")).or_insert((0, 0.0));
                e.0 += 1;
                e.1 += dur_ns;
            }
            "i" => {
                let e = agg.entry((name, "instant")).or_insert((0, 0.0));
                e.0 += 1;
            }
            _ => {}
        }
    }

    let mut rows: Vec<SummaryRow> = agg
        .into_iter()
        .map(|((name, kind), (count, total_ns))| SummaryRow { name, kind, count, total_ns })
        .collect();
    rows.sort_by(|a, b| {
        b.total_ns.partial_cmp(&a.total_ns).unwrap_or(std::cmp::Ordering::Equal)
    });

    let other = doc.get("otherData");
    let wall_virtual_ns = other.and_then(|o| o.get("wall_virtual_ns")).and_then(Value::as_f64);
    let dropped_events = other
        .and_then(|o| o.get("dropped_events"))
        .and_then(Value::as_f64)
        .unwrap_or(0.0) as u64;

    Ok(TraceSummary {
        rows,
        round_span_ns,
        wall_virtual_ns,
        events: stats.events,
        slot_tracks: stats.slot_tracks,
        dropped_events,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{names, slot_track, TraceConfig, TraceSinkKind, Tracer, TRACK_ENGINE};

    #[test]
    fn summarize_reconstructs_round_time() {
        let mut t = Tracer::new(&TraceConfig { sink: TraceSinkKind::Chrome, ring: 0 });
        // Two rounds, 10_000 ns and 20_000 ns, with nested work.
        t.begin(names::ROUND, TRACK_ENGINE, 0);
        let op = t.intern("fx_matmul");
        t.complete(op, TRACK_ENGINE, 2_000, 4_000, 0);
        t.instant(names::TOKEN, slot_track(0), 9_000, 1);
        t.end(names::ROUND, TRACK_ENGINE, 10_000);
        t.begin(names::ROUND, TRACK_ENGINE, 10_000);
        t.complete(op, TRACK_ENGINE, 12_000, 6_000, 0);
        t.end(names::ROUND, TRACK_ENGINE, 30_000);
        let doc = chrome::export(&t, &[("wall_virtual_ns", 30_000.0)]);
        let sum = summarize(&doc).expect("summarize");
        assert_eq!(sum.round_span_ns, 30_000.0);
        assert_eq!(sum.tiling_delta(), Some(0.0));
        assert_eq!(sum.slot_tracks, 1);
        let round = sum.rows.iter().find(|r| r.name == "round").unwrap();
        assert_eq!(round.count, 2);
        let op_row = sum.rows.iter().find(|r| r.name == "fx_matmul").unwrap();
        assert_eq!(op_row.count, 2);
        assert_eq!(op_row.total_ns, 10_000.0);
        let md = sum.table().to_markdown();
        assert!(md.contains("T1"), "{md}");
        assert!(md.contains("fx_matmul"), "{md}");
        assert!(md.contains("Tiling check"), "{md}");
    }

    #[test]
    fn summarize_rejects_malformed_trace() {
        let doc =
            crate::report::json::parse(r#"{"traceEvents": [{"ph": "B", "name": "round"}]}"#)
                .unwrap();
        assert!(summarize(&doc).is_err());
    }
}

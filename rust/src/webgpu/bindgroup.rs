//! Bind group layouts and bind groups.
//!
//! Bind group creation is one of the three per-dispatch costs the paper's
//! C++ profiler instruments (encoder creation, bind group creation,
//! submission). Layout/group compatibility is re-validated at dispatch time,
//! matching WebGPU's draw-time validation rules.



use super::buffer::BufferId;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BindGroupLayoutId(pub u64);

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BindGroupId(pub u64);

/// Binding slot type (compute subset of `GPUBindGroupLayoutEntry`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BindingType {
    /// Read-only storage buffer (kernel input).
    ReadOnlyStorage,
    /// Read-write storage buffer (kernel output).
    Storage,
    /// Uniform buffer (small parameters).
    Uniform,
}

#[derive(Debug, Clone)]
pub struct BindGroupLayoutDesc {
    pub label: String,
    /// Binding index -> type, dense from 0.
    pub entries: Vec<BindingType>,
}

#[derive(Debug, Clone)]
pub(crate) struct BindGroupLayout {
    pub desc: BindGroupLayoutDesc,
}

/// One bound buffer.
#[derive(Debug, Clone, Copy)]
pub struct BindGroupEntry {
    pub binding: usize,
    pub buffer: BufferId,
    pub offset: usize,
    /// Bound byte range length.
    pub size: usize,
}

#[derive(Debug, Clone)]
pub struct BindGroupDesc {
    pub label: String,
    pub layout: BindGroupLayoutId,
    pub entries: Vec<BindGroupEntry>,
}

#[derive(Debug, Clone)]
pub(crate) struct BindGroup {
    pub desc: BindGroupDesc,
}

//! GPU buffers: usage-flagged byte arrays with create/destroy lifecycle.
//!
//! Usage flags are validated on every operation exactly as WebGPU does —
//! binding a buffer without `STORAGE` into a storage slot, writing one
//! without `COPY_DST`, or mapping one without `MAP_READ` is a validation
//! error, and that validation work is part of the per-dispatch cost the
//! paper characterizes.



/// Buffer usage bitflags (subset of `GPUBufferUsage`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BufferUsage(pub u32);

impl BufferUsage {
    pub const MAP_READ: BufferUsage = BufferUsage(1 << 0);
    pub const COPY_SRC: BufferUsage = BufferUsage(1 << 2);
    pub const COPY_DST: BufferUsage = BufferUsage(1 << 3);
    pub const UNIFORM: BufferUsage = BufferUsage(1 << 6);
    pub const STORAGE: BufferUsage = BufferUsage(1 << 7);

    pub fn contains(self, other: BufferUsage) -> bool {
        self.0 & other.0 == other.0
    }

    pub fn is_empty(self) -> bool {
        self.0 == 0
    }
}

impl std::ops::BitOr for BufferUsage {
    type Output = BufferUsage;
    fn bitor(self, rhs: BufferUsage) -> BufferUsage {
        BufferUsage(self.0 | rhs.0)
    }
}

/// Handle to a device buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BufferId(pub u64);

#[derive(Debug, Clone)]
pub struct BufferDesc {
    pub label: String,
    pub size: usize,
    pub usage: BufferUsage,
}

/// A live buffer: descriptor + backing store.
#[derive(Debug)]
pub(crate) struct Buffer {
    pub desc: BufferDesc,
    pub data: Vec<u8>,
    pub destroyed: bool,
}

impl Buffer {
    pub fn new(desc: BufferDesc) -> Self {
        let size = desc.size;
        Buffer { desc, data: vec![0u8; size], destroyed: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usage_flag_algebra() {
        let u = BufferUsage::STORAGE | BufferUsage::COPY_DST;
        assert!(u.contains(BufferUsage::STORAGE));
        assert!(u.contains(BufferUsage::COPY_DST));
        assert!(!u.contains(BufferUsage::MAP_READ));
        assert!(!BufferUsage(0).contains(BufferUsage::STORAGE) || false);
        assert!(BufferUsage(0).is_empty());
    }

    #[test]
    fn buffer_backing_store_zeroed() {
        let b = Buffer::new(BufferDesc {
            label: "t".into(),
            size: 16,
            usage: BufferUsage::STORAGE,
        });
        assert_eq!(b.data.len(), 16);
        assert!(b.data.iter().all(|&x| x == 0));
    }
}

//! Virtual + real clocks and the per-phase dispatch timeline.
//!
//! The virtual clock models CPU time (API overhead) and the GPU completion
//! frontier separately, reproducing WebGPU's asynchronous `queue.Submit()`
//! semantics: CPU-side costs do not directly sum to wall-clock because the
//! GPU executes operation N while the CPU encodes N+1 (the paper's ~12 ms
//! "GPU/CPU overlap" residual in Table 4).



/// The eight CPU-side phases of one dispatch, in call order (Table 20).
pub const DISPATCH_PHASES: [&str; 8] = [
    "encoder_create",
    "pass_begin",
    "set_pipeline",
    "set_bind_group",
    "dispatch_call",
    "pass_end",
    "encoder_finish",
    "submit",
];

/// Deterministic xorshift64* RNG for calibrated jitter — the tables report
/// CV/CI/p-values, so runs need realistic variance without nondeterminism.
#[derive(Debug, Clone)]
pub struct Jitter {
    state: u64,
}

impl Jitter {
    pub fn new(seed: u64) -> Self {
        Jitter { state: seed.max(1) }
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// `base * (1 +/- pct)`, uniform.
    pub fn apply(&mut self, base_ns: u64, pct: f64) -> u64 {
        if pct <= 0.0 || base_ns == 0 {
            return base_ns;
        }
        let f = 1.0 + pct * (2.0 * self.next_f64() - 1.0);
        (base_ns as f64 * f).round().max(0.0) as u64
    }
}

/// Virtual CPU clock + GPU completion frontier (nanoseconds).
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    /// CPU-side virtual time.
    pub cpu_ns: u64,
    /// Time at which all submitted GPU work completes.
    pub gpu_done_ns: u64,
    /// Virtual time of the last queue submit (for rate-limiting models).
    pub last_submit_ns: u64,
    /// Total GPU busy time accumulated (kernel execution).
    pub gpu_busy_ns: u64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Advance CPU time (an API call's CPU-side cost).
    pub fn advance_cpu(&mut self, ns: u64) {
        self.cpu_ns += ns;
    }

    /// Enqueue GPU work at the current frontier; returns its completion time.
    pub fn enqueue_gpu(&mut self, kernel_ns: u64) -> u64 {
        let start = self.gpu_done_ns.max(self.cpu_ns);
        self.gpu_done_ns = start + kernel_ns;
        self.gpu_busy_ns += kernel_ns;
        self.gpu_done_ns
    }

    /// Block the CPU until the GPU frontier (device.poll / map wait), then
    /// pay `sync_ns` of synchronization cost.
    pub fn sync(&mut self, sync_ns: u64) {
        self.cpu_ns = self.cpu_ns.max(self.gpu_done_ns) + sync_ns;
    }

    /// Wall-clock "now": CPU time (the GPU frontier only matters at sync).
    pub fn now_ns(&self) -> u64 {
        self.cpu_ns
    }
}

/// Accumulated per-phase timing: virtual (calibrated model) and real
/// (measured on this host's substrate), plus call counts — the raw material
/// for Table 20.
#[derive(Debug, Clone, Default)]
pub struct PhaseTimeline {
    pub virtual_ns: [u64; 8],
    pub real_ns: [u64; 8],
    pub calls: [u64; 8],
    pub kernel_virtual_ns: u64,
    pub sync_virtual_ns: u64,
    pub sync_calls: u64,
}

impl PhaseTimeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, phase: usize, virtual_ns: u64, real_ns: u64) {
        self.virtual_ns[phase] += virtual_ns;
        self.real_ns[phase] += real_ns;
        self.calls[phase] += 1;
    }

    pub fn total_virtual_ns(&self) -> u64 {
        self.virtual_ns.iter().sum()
    }

    pub fn total_real_ns(&self) -> u64 {
        self.real_ns.iter().sum()
    }

    /// Number of dispatches recorded (dispatch_call phase count).
    pub fn dispatches(&self) -> u64 {
        self.calls[4]
    }

    pub fn reset(&mut self) {
        *self = Self::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let mut a = Jitter::new(7);
        let mut b = Jitter::new(7);
        for _ in 0..100 {
            let x = a.apply(1000, 0.05);
            assert_eq!(x, b.apply(1000, 0.05));
            assert!((950..=1050).contains(&x), "jitter out of band: {x}");
        }
    }

    #[test]
    fn jitter_zero_pct_is_identity() {
        let mut j = Jitter::new(1);
        assert_eq!(j.apply(12345, 0.0), 12345);
    }

    #[test]
    fn gpu_overlap_semantics() {
        let mut c = VirtualClock::new();
        c.advance_cpu(100);
        c.enqueue_gpu(1000); // gpu busy 100..1100
        c.advance_cpu(50); // cpu at 150, gpu still running
        assert_eq!(c.cpu_ns, 150);
        assert_eq!(c.gpu_done_ns, 1100);
        c.sync(10);
        assert_eq!(c.cpu_ns, 1110); // waited for gpu then paid sync
    }

    #[test]
    fn gpu_queue_serializes() {
        let mut c = VirtualClock::new();
        c.enqueue_gpu(500);
        c.enqueue_gpu(500);
        assert_eq!(c.gpu_done_ns, 1000);
        assert_eq!(c.gpu_busy_ns, 1000);
    }

    #[test]
    fn sync_after_gpu_done_is_cheap() {
        let mut c = VirtualClock::new();
        c.enqueue_gpu(100);
        c.advance_cpu(5000); // cpu long past gpu completion
        c.sync(10);
        assert_eq!(c.cpu_ns, 5010);
    }

    #[test]
    fn timeline_accumulates() {
        let mut t = PhaseTimeline::new();
        t.record(0, 10, 20);
        t.record(0, 10, 20);
        t.record(7, 5, 5);
        assert_eq!(t.virtual_ns[0], 20);
        assert_eq!(t.calls[0], 2);
        assert_eq!(t.total_virtual_ns(), 25);
        assert_eq!(t.total_real_ns(), 45);
    }
}

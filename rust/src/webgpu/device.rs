//! The device: resource tables, per-call validation, phase-timed API.
//!
//! Every public method performs the real validation work a WebGPU
//! implementation performs (that work *is* the subject of the paper) under
//! the wall clock, and advances the virtual clock by the calibrated phase
//! cost of the device's [`ImplementationProfile`].

use std::collections::HashMap;
use std::time::Instant;

use super::bindgroup::{
    BindGroup, BindGroupDesc, BindGroupId, BindGroupLayout, BindGroupLayoutDesc,
    BindGroupLayoutId,
};
use super::buffer::{Buffer, BufferDesc, BufferId, BufferUsage};
use super::clock::{Jitter, PhaseTimeline, VirtualClock};
use super::encoder::{
    Command, CommandBuffer, CommandBufferId, CommandEncoder, CommandEncoderId,
    EncoderState,
};
use super::fault::{FaultInjector, FaultKind};
use super::limits::Limits;
use super::pipeline::{
    ComputePipeline, ComputePipelineId, ShaderModule, ShaderModuleDesc,
    ShaderModuleId,
};
use super::profile::ImplementationProfile;
use super::validation;
use crate::tensor::{DType, Tensor, TensorData};
use crate::trace::{names as trace_names, Tracer, TRACK_ENGINE};
use crate::{Error, Result};

/// Executes a named AOT kernel. Implemented by the PJRT runtime; a
/// [`NullRunner`] is provided for pure dispatch-overhead microbenchmarks
/// (the paper's exp6/exp7 use trivial shaders for the same reason).
pub trait KernelRunner {
    /// Run `kernel` on `inputs`; returns (outputs, measured wall ns, flops).
    fn run(
        &self,
        kernel: &str,
        inputs: &[Tensor],
        out_specs: &[super::pipeline::KernelIoSpec],
    ) -> Result<(Vec<Tensor>, u64, f64)>;
}

/// Produces zero-filled outputs without touching PJRT — isolates pure
/// dispatch overhead.
pub struct NullRunner;

impl KernelRunner for NullRunner {
    fn run(
        &self,
        _kernel: &str,
        _inputs: &[Tensor],
        out_specs: &[super::pipeline::KernelIoSpec],
    ) -> Result<(Vec<Tensor>, u64, f64)> {
        let outs = out_specs
            .iter()
            .map(|s| match s.dtype {
                DType::F32 => Tensor {
                    shape: s.shape.clone(),
                    data: TensorData::F32(vec![0.0; s.numel()]),
                },
                DType::I32 => Tensor {
                    shape: s.shape.clone(),
                    data: TensorData::I32(vec![0; s.numel()]),
                },
            })
            .collect();
        Ok((outs, 0, 0.0))
    }
}

/// How kernel execution advances the virtual GPU frontier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelTimePolicy {
    /// Use the measured PJRT wall time (the real system on this host).
    Measured,
    /// Use `flops / profile.kernel_gflops` (simulated paper hardware).
    Calibrated,
}

/// Running counters (resource lifecycle + error accounting).
#[derive(Debug, Clone, Copy, Default)]
pub struct DeviceStats {
    pub buffers_created: u64,
    pub buffers_destroyed: u64,
    pub bind_groups_created: u64,
    pub pipelines_created: u64,
    pub encoders_created: u64,
    pub submits: u64,
    pub dispatches_executed: u64,
    pub bytes_written: u64,
    pub bytes_mapped: u64,
    pub validation_errors: u64,
}

pub struct Device {
    pub profile: ImplementationProfile,
    pub limits: Limits,
    pub clock: VirtualClock,
    pub timeline: PhaseTimeline,
    pub stats: DeviceStats,
    pub kernel_time_policy: KernelTimePolicy,
    /// Span tracer + always-on metrics registry. Disabled (Null sink) on
    /// a bare device; the serving engine installs a configured tracer.
    /// Instrumentation only READS the virtual clock — it never advances
    /// it and never draws jitter — so enabling tracing cannot perturb
    /// token streams.
    pub trace: Tracer,
    /// True when a sync happened since the last submit — Metal-style
    /// sequential backpressure only builds up under back-to-back submits.
    synced_since_submit: bool,
    /// Per-run correlated drift (thermal/scheduler state): real systems show
    /// run-level variance that per-dispatch jitter alone averages away over
    /// thousands of dispatches. Sampled per reseed; drives the 1-4% CV the
    /// paper reports.
    drift: f64,
    jitter: Jitter,
    /// Optional deterministic fault injection (CI-reproducible failure
    /// modes). `None` in normal operation: the checks cost one branch.
    fault: Option<FaultInjector>,
    next_id: u64,
    pub(crate) buffers: HashMap<BufferId, Buffer>,
    layouts: HashMap<BindGroupLayoutId, BindGroupLayout>,
    groups: HashMap<BindGroupId, BindGroup>,
    modules: HashMap<ShaderModuleId, ShaderModule>,
    pipelines: HashMap<ComputePipelineId, ComputePipeline>,
    encoders: HashMap<CommandEncoderId, CommandEncoder>,
    cmdbufs: HashMap<CommandBufferId, CommandBuffer>,
}

// Upload cost model: folded into framework overhead in the paper's
// accounting; small constants here so write_buffer is not free.
const WRITE_FIXED_NS: u64 = 1_000;
const WRITE_PER_BYTE_NS: f64 = 0.05;

impl Device {
    pub fn new(profile: ImplementationProfile) -> Self {
        Self::with_limits(profile, Limits::default())
    }

    pub fn with_limits(profile: ImplementationProfile, limits: Limits) -> Self {
        Device {
            jitter: Jitter::new(0x5EED_0001),
            profile,
            limits,
            clock: VirtualClock::new(),
            timeline: PhaseTimeline::new(),
            stats: DeviceStats::default(),
            kernel_time_policy: KernelTimePolicy::Measured,
            trace: Tracer::disabled(),
            synced_since_submit: true,
            drift: 1.0,
            fault: None,
            next_id: 1,
            buffers: HashMap::new(),
            layouts: HashMap::new(),
            groups: HashMap::new(),
            modules: HashMap::new(),
            pipelines: HashMap::new(),
            encoders: HashMap::new(),
            cmdbufs: HashMap::new(),
        }
    }

    /// Reseed the jitter stream (used by the bench protocol so independent
    /// runs see independent variance).
    pub fn reseed_jitter(&mut self, seed: u64) {
        self.jitter = Jitter::new(seed);
        // Correlated per-run drift: +/- jitter_pct around nominal, scaled to
        // match the paper's run-level CV (0.9-4%).
        let u = self.jitter.next_f64();
        self.drift = 1.0 + self.profile.jitter_pct * (2.0 * u - 1.0);
    }

    /// Apply drift + jitter to an arbitrary virtual cost (framework
    /// overhead, sync costs) so run-level variance covers the whole per-op
    /// budget, not just the dispatch phases.
    pub fn drifted_cost(&mut self, base_ns: u64) -> u64 {
        let base = (base_ns as f64 * self.drift) as u64;
        self.jitter.apply(base, self.profile.jitter_pct)
    }

    fn id(&mut self) -> u64 {
        let v = self.next_id;
        self.next_id += 1;
        v
    }

    /// Record one dispatch phase: virtual calibrated cost + measured real ns.
    fn phase(&mut self, idx: usize, t0: Instant) {
        let base = (self.profile.phases.0[idx] as f64 * self.drift) as u64;
        let v = self.jitter.apply(base, self.profile.jitter_pct);
        self.clock.advance_cpu(v);
        let real = t0.elapsed().as_nanos() as u64;
        self.timeline.record(idx, v, real);
    }

    fn fail(&mut self, e: Error) -> Error {
        self.stats.validation_errors += 1;
        e
    }

    // ---------------------------------------------------- fault injection --
    /// Arm deterministic fault injection. Installed AFTER construction-
    /// time setup (plan build, weight pinning) by callers that want only
    /// steady-state opportunities to fault.
    pub fn install_fault_injector(&mut self, inj: FaultInjector) {
        self.fault = Some(inj);
    }

    /// Faults fired so far (0 when no injector is armed).
    pub fn faults_injected(&self) -> u64 {
        self.fault.as_ref().map_or(0, |f| f.injected())
    }

    /// Convert a fired fault kind into its typed error. `DispatchFail`/
    /// `AllocFail`/`MapTimeout` are transient (the one-shot trigger is
    /// consumed, an identical retry succeeds); `DeviceLost` is fatal.
    fn fault_error(&mut self, kind: FaultKind, what: &str) -> Error {
        let ts = self.clock.now_ns();
        self.trace.instant(trace_names::FAULT, TRACK_ENGINE, ts, kind as u64);
        let e = match kind {
            FaultKind::DispatchFail => {
                Error::Transient(format!("injected dispatch failure at {what}"))
            }
            FaultKind::AllocFail => {
                Error::Transient(format!("injected allocation failure at {what}"))
            }
            FaultKind::MapTimeout => {
                Error::Transient(format!("injected map timeout at {what}"))
            }
            FaultKind::DeviceLost => {
                Error::DeviceLost(format!("injected device loss at {what}"))
            }
        };
        self.fail(e)
    }

    // ------------------------------------------------------------ buffers --
    pub fn create_buffer(&mut self, desc: BufferDesc) -> Result<BufferId> {
        if let Err(e) = validation::validate_buffer_desc(&desc, &self.limits) {
            return Err(self.fail(e));
        }
        if let Some(kind) = self.fault.as_mut().and_then(|f| f.on_alloc()) {
            return Err(self.fault_error(kind, "create_buffer"));
        }
        let id = BufferId(self.id());
        self.buffers.insert(id, Buffer::new(desc));
        self.stats.buffers_created += 1;
        Ok(id)
    }

    pub fn destroy_buffer(&mut self, id: BufferId) -> Result<()> {
        let buf = self
            .buffers
            .get_mut(&id)
            .ok_or_else(|| Error::InvalidResource(format!("buffer {id:?}")))?;
        buf.destroyed = true;
        buf.data = Vec::new();
        self.stats.buffers_destroyed += 1;
        Ok(())
    }

    pub fn buffer_size(&self, id: BufferId) -> Result<usize> {
        self.buffers
            .get(&id)
            .filter(|b| !b.destroyed)
            .map(|b| b.desc.size)
            .ok_or_else(|| Error::InvalidResource(format!("buffer {id:?}")))
    }

    /// `queue.writeBuffer`: host -> device copy.
    pub fn write_buffer(&mut self, id: BufferId, offset: usize, data: &[u8]) -> Result<()> {
        {
            let buf = self
                .buffers
                .get(&id)
                .ok_or_else(|| Error::InvalidResource(format!("buffer {id:?}")))?;
            if let Err(e) = validation::validate_write(buf, offset, data.len()) {
                return Err(self.fail(e));
            }
        }
        let buf = self.buffers.get_mut(&id).unwrap();
        buf.data[offset..offset + data.len()].copy_from_slice(data);
        self.stats.bytes_written += data.len() as u64;
        let cost = WRITE_FIXED_NS + (data.len() as f64 * WRITE_PER_BYTE_NS) as u64;
        let cost = self.jitter.apply(cost, self.profile.jitter_pct);
        let t0 = self.clock.now_ns();
        self.clock.advance_cpu(cost);
        self.trace.complete(trace_names::UPLOAD, TRACK_ENGINE, t0, cost, data.len() as u64);
        Ok(())
    }

    /// `encoder.clearBuffer`: zero-fill a buffer device-side. No host
    /// bytes cross the bus (stats.bytes_written is untouched) — the cost
    /// is a small fixed charge, like any other queue operation. Used when
    /// a recycled pool buffer becomes a fresh session's KV cache.
    pub fn clear_buffer(&mut self, id: BufferId) -> Result<()> {
        let destroyed = self
            .buffers
            .get(&id)
            .map(|b| b.destroyed)
            .ok_or_else(|| Error::InvalidResource(format!("buffer {id:?}")))?;
        if destroyed {
            return Err(self.fail(Error::Validation("clear of destroyed buffer".into())));
        }
        self.buffers.get_mut(&id).unwrap().data.fill(0);
        let cost = self.jitter.apply(WRITE_FIXED_NS, self.profile.jitter_pct);
        self.clock.advance_cpu(cost);
        Ok(())
    }

    /// Raw (non-mapped) access for host-side ops — models torch-webgpu's
    /// CPU-side tensor metadata path, NOT a GPU readback (no sync cost).
    /// Only `map_read` models the synchronizing readback.
    pub fn peek_buffer(&self, id: BufferId) -> Result<&[u8]> {
        let buf = self
            .buffers
            .get(&id)
            .ok_or_else(|| Error::InvalidResource(format!("buffer {id:?}")))?;
        if buf.destroyed {
            return Err(Error::InvalidResource(format!("buffer {id:?} destroyed")));
        }
        Ok(&buf.data)
    }

    /// `mapAsync(MAP_READ)` + wait + copy out: synchronizes with the GPU
    /// frontier and pays the backend's map cost (Vulkan ~0.1 ms fixed,
    /// Metal ~1.8 ms — Appendix H), plus a per-byte transfer cost.
    pub fn map_read(&mut self, id: BufferId) -> Result<Vec<u8>> {
        let (bytes, usage) = {
            let buf = self
                .buffers
                .get(&id)
                .ok_or_else(|| Error::InvalidResource(format!("buffer {id:?}")))?;
            if buf.destroyed {
                return Err(self.fail(Error::InvalidResource(format!(
                    "buffer {id:?} destroyed"
                ))));
            }
            (buf.data.clone(), buf.desc.usage)
        };
        if !usage.contains(BufferUsage::MAP_READ) {
            return Err(self.fail(Error::Validation(
                "map_read requires MAP_READ usage".into(),
            )));
        }
        let cost = self.profile.map_fixed_ns
            + (bytes.len() as f64 * self.profile.map_per_byte_ns) as u64;
        let cost = self.drifted_cost(cost);
        let t0 = self.clock.now_ns();
        self.clock.sync(cost);
        self.synced_since_submit = true;
        self.stats.bytes_mapped += bytes.len() as u64;
        self.timeline.sync_virtual_ns += cost;
        self.timeline.sync_calls += 1;
        let waited = self.clock.now_ns() - t0;
        self.trace.metrics.map_wait_ns.record(waited);
        self.trace.complete(trace_names::READBACK, TRACK_ENGINE, t0, waited, bytes.len() as u64);
        Ok(bytes)
    }

    /// Coalesced readback: map several buffers behind ONE synchronization
    /// point. The GPU-frontier wait and the backend's fixed map cost
    /// (`map_fixed_ns` — Vulkan ~0.1 ms, Metal ~1.8 ms) are paid once; only
    /// the per-byte transfer cost scales with the number of buffers. This
    /// is the serving-side fixed-cost amortization the multi-session
    /// scheduler exploits: N concurrent decode steps share one sync instead
    /// of paying one each. With a single buffer the cost model (and the
    /// jitter draw sequence) is identical to [`Device::map_read`].
    pub fn map_read_many(&mut self, ids: &[BufferId]) -> Result<Vec<Vec<u8>>> {
        if ids.is_empty() {
            return Ok(Vec::new());
        }
        if let Some(kind) = self.fault.as_mut().and_then(|f| f.on_map()) {
            return Err(self.fault_error(kind, "map_read_many"));
        }
        let mut out: Vec<Vec<u8>> = Vec::with_capacity(ids.len());
        let mut total = 0usize;
        for &id in ids {
            let (bytes, usage) = {
                let buf = self
                    .buffers
                    .get(&id)
                    .ok_or_else(|| Error::InvalidResource(format!("buffer {id:?}")))?;
                if buf.destroyed {
                    return Err(self.fail(Error::InvalidResource(format!(
                        "buffer {id:?} destroyed"
                    ))));
                }
                (buf.data.clone(), buf.desc.usage)
            };
            if !usage.contains(BufferUsage::MAP_READ) {
                return Err(self.fail(Error::Validation(
                    "map_read requires MAP_READ usage".into(),
                )));
            }
            total += bytes.len();
            out.push(bytes);
        }
        let cost = self.profile.map_fixed_ns
            + (total as f64 * self.profile.map_per_byte_ns) as u64;
        let cost = self.drifted_cost(cost);
        let t0 = self.clock.now_ns();
        self.clock.sync(cost);
        self.synced_since_submit = true;
        self.stats.bytes_mapped += total as u64;
        self.timeline.sync_virtual_ns += cost;
        self.timeline.sync_calls += 1;
        let waited = self.clock.now_ns() - t0;
        self.trace.metrics.map_wait_ns.record(waited);
        self.trace.complete(trace_names::READBACK, TRACK_ENGINE, t0, waited, total as u64);
        Ok(out)
    }

    /// Ranged coalesced readback: map several `(buffer, offset, len)`
    /// windows behind ONE synchronization point. The GPU-frontier wait and
    /// the backend's fixed map cost are paid once (like
    /// [`Device::map_read_many`]); the per-byte transfer cost scales with
    /// the SUM of the requested windows, not whole buffers. This is what
    /// makes per-block KV paging cheaper than whole-set spills: a page-out
    /// of k blocks moves k x block bytes, not layers x max_seq.
    pub fn map_read_ranges(
        &mut self,
        ranges: &[(BufferId, usize, usize)],
    ) -> Result<Vec<Vec<u8>>> {
        if ranges.is_empty() {
            return Ok(Vec::new());
        }
        if let Some(kind) = self.fault.as_mut().and_then(|f| f.on_map()) {
            return Err(self.fault_error(kind, "map_read_ranges"));
        }
        let mut out: Vec<Vec<u8>> = Vec::with_capacity(ranges.len());
        let mut total = 0usize;
        for &(id, offset, len) in ranges {
            let (bytes, usage, size) = {
                let buf = self
                    .buffers
                    .get(&id)
                    .ok_or_else(|| Error::InvalidResource(format!("buffer {id:?}")))?;
                if buf.destroyed {
                    return Err(self.fail(Error::InvalidResource(format!(
                        "buffer {id:?} destroyed"
                    ))));
                }
                let size = buf.data.len();
                if offset + len > size {
                    (Vec::new(), buf.desc.usage, size)
                } else {
                    (
                        buf.data[offset..offset + len].to_vec(),
                        buf.desc.usage,
                        size,
                    )
                }
            };
            if !usage.contains(BufferUsage::MAP_READ) {
                return Err(self.fail(Error::Validation(
                    "map_read requires MAP_READ usage".into(),
                )));
            }
            if offset + len > size {
                return Err(self.fail(Error::Validation(format!(
                    "map range {offset}+{len} past buffer size {size}"
                ))));
            }
            total += len;
            out.push(bytes);
        }
        let cost = self.profile.map_fixed_ns
            + (total as f64 * self.profile.map_per_byte_ns) as u64;
        let cost = self.drifted_cost(cost);
        let t0 = self.clock.now_ns();
        self.clock.sync(cost);
        self.synced_since_submit = true;
        self.stats.bytes_mapped += total as u64;
        self.timeline.sync_virtual_ns += cost;
        self.timeline.sync_calls += 1;
        let waited = self.clock.now_ns() - t0;
        self.trace.metrics.map_wait_ns.record(waited);
        self.trace.complete(trace_names::READBACK, TRACK_ENGINE, t0, waited, total as u64);
        Ok(out)
    }

    /// `device.poll(Wait)` / `onSubmittedWorkDone`: block until the GPU
    /// frontier, paying the profile's sync cost. This is what single-op
    /// benchmarks pay per dispatch (the ~20x conflation).
    pub fn poll_wait(&mut self) {
        let cost = self.drifted_cost(self.profile.sync_ns);
        self.clock.sync(cost);
        self.synced_since_submit = true;
        self.timeline.sync_virtual_ns += cost;
        self.timeline.sync_calls += 1;
    }

    // -------------------------------------------------------- bind groups --
    pub fn create_bind_group_layout(
        &mut self,
        desc: BindGroupLayoutDesc,
    ) -> Result<BindGroupLayoutId> {
        if desc.entries.is_empty() {
            return Err(self.fail(Error::Validation("empty bind group layout".into())));
        }
        if desc.entries.len() > self.limits.max_bindings_per_group {
            return Err(self.fail(Error::LimitExceeded(format!(
                "{} bindings > max {}",
                desc.entries.len(),
                self.limits.max_bindings_per_group
            ))));
        }
        let id = BindGroupLayoutId(self.id());
        self.layouts.insert(id, BindGroupLayout { desc });
        Ok(id)
    }

    pub fn create_bind_group(&mut self, desc: BindGroupDesc) -> Result<BindGroupId> {
        let t0 = Instant::now();
        {
            let layout = self.layouts.get(&desc.layout).ok_or_else(|| {
                Error::InvalidResource(format!("layout {:?}", desc.layout))
            })?;
            if let Err(e) =
                validation::validate_bind_group(&desc, &layout.desc, &self.buffers, &self.limits)
            {
                return Err(self.fail(e));
            }
        }
        let id = BindGroupId(self.id());
        self.groups.insert(id, BindGroup { desc });
        self.stats.bind_groups_created += 1;
        // Bind group creation cost rides the set_bind_group phase budget at
        // creation time in our model (the paper's profiler pools them).
        self.phase(3, t0);
        Ok(id)
    }

    // ----------------------------------------------------------- pipeline --
    pub fn create_shader_module(&mut self, desc: ShaderModuleDesc) -> Result<ShaderModuleId> {
        if desc.inputs.is_empty() && desc.outputs.is_empty() {
            return Err(self.fail(Error::Validation(format!(
                "shader module {} has no I/O",
                desc.label
            ))));
        }
        let id = ShaderModuleId(self.id());
        self.modules.insert(id, ShaderModule { desc });
        Ok(id)
    }

    pub fn create_compute_pipeline(
        &mut self,
        label: &str,
        module: ShaderModuleId,
        layout: BindGroupLayoutId,
    ) -> Result<ComputePipelineId> {
        let m = self
            .modules
            .get(&module)
            .ok_or_else(|| Error::InvalidResource(format!("module {module:?}")))?;
        let l = self
            .layouts
            .get(&layout)
            .ok_or_else(|| Error::InvalidResource(format!("layout {layout:?}")))?;
        if let Err(e) = validation::validate_pipeline_interface(&m.desc, &l.desc) {
            return Err(self.fail(e));
        }
        let (n_inputs, n_outputs) = (m.desc.inputs.len(), m.desc.outputs.len());
        let id = ComputePipelineId(self.id());
        self.pipelines.insert(
            id,
            ComputePipeline { label: label.to_string(), module, layout, n_inputs, n_outputs },
        );
        self.stats.pipelines_created += 1;
        Ok(id)
    }

    // ------------------------------------------------------------ encoder --
    pub fn create_command_encoder(&mut self, label: &str) -> CommandEncoderId {
        let t0 = Instant::now();
        let id = CommandEncoderId(self.id());
        self.encoders.insert(id, CommandEncoder::new(label.to_string()));
        self.stats.encoders_created += 1;
        self.phase(0, t0);
        id
    }

    fn encoder_mut(&mut self, id: CommandEncoderId) -> Result<&mut CommandEncoder> {
        self.encoders
            .get_mut(&id)
            .ok_or_else(|| Error::InvalidResource(format!("encoder {id:?}")))
    }

    pub fn begin_compute_pass(&mut self, enc: CommandEncoderId) -> Result<()> {
        let t0 = Instant::now();
        let e = self.encoder_mut(enc)?;
        if e.state != EncoderState::Open {
            let msg = format!("begin_compute_pass in state {:?}", e.state);
            return Err(self.fail(Error::Validation(msg)));
        }
        e.state = EncoderState::PassOpen;
        e.current_pipeline = None;
        e.current_bind_group = None;
        self.phase(1, t0);
        Ok(())
    }

    pub fn set_pipeline(&mut self, enc: CommandEncoderId, p: ComputePipelineId) -> Result<()> {
        let t0 = Instant::now();
        if !self.pipelines.contains_key(&p) {
            return Err(self.fail(Error::InvalidResource(format!("pipeline {p:?}"))));
        }
        let e = self.encoder_mut(enc)?;
        if e.state != EncoderState::PassOpen {
            return Err(self.fail(Error::Validation("set_pipeline outside pass".into())));
        }
        e.current_pipeline = Some(p);
        e.commands.push(Command::SetPipeline(p));
        self.phase(2, t0);
        Ok(())
    }

    pub fn set_bind_group(&mut self, enc: CommandEncoderId, g: BindGroupId) -> Result<()> {
        let t0 = Instant::now();
        if !self.groups.contains_key(&g) {
            return Err(self.fail(Error::InvalidResource(format!("bind group {g:?}"))));
        }
        let e = self.encoder_mut(enc)?;
        if e.state != EncoderState::PassOpen {
            return Err(self.fail(Error::Validation("set_bind_group outside pass".into())));
        }
        e.current_bind_group = Some(g);
        e.commands.push(Command::SetBindGroup(g));
        // recorded as part of the set_bind_group phase; bind group *creation*
        // already charged its own slice.
        let t1 = Instant::now();
        let _ = t1;
        self.timeline.record(3, 0, t0.elapsed().as_nanos() as u64);
        Ok(())
    }

    pub fn dispatch_workgroups(
        &mut self,
        enc: CommandEncoderId,
        x: u32,
        y: u32,
        z: u32,
    ) -> Result<()> {
        let t0 = Instant::now();
        let max = self.limits.max_compute_workgroups_per_dimension;
        if x == 0 || y == 0 || z == 0 {
            return Err(self.fail(Error::Validation("zero workgroup count".into())));
        }
        if x > max || y > max || z > max {
            return Err(self.fail(Error::LimitExceeded(format!(
                "workgroups ({x},{y},{z}) > max {max}"
            ))));
        }
        // Draw-time validation: pipeline + bind group set and compatible.
        let (pipe_id, group_id, estate) = {
            let e = self.encoder_mut(enc)?;
            (e.current_pipeline, e.current_bind_group, e.state)
        };
        if estate != EncoderState::PassOpen {
            return Err(self.fail(Error::Validation("dispatch outside pass".into())));
        }
        let pipe_id = match pipe_id {
            Some(p) => p,
            None => return Err(self.fail(Error::Validation("dispatch without pipeline".into()))),
        };
        let group_id = match group_id {
            Some(g) => g,
            None => return Err(self.fail(Error::Validation("dispatch without bind group".into()))),
        };
        let pipe = &self.pipelines[&pipe_id];
        let group = &self.groups[&group_id];
        if group.desc.layout != pipe.layout {
            return Err(self.fail(Error::Validation(format!(
                "bind group layout {:?} incompatible with pipeline layout {:?}",
                group.desc.layout, pipe.layout
            ))));
        }
        if group.desc.entries.len() != pipe.n_inputs + pipe.n_outputs {
            return Err(self.fail(Error::Validation(format!(
                "bind group has {} entries, pipeline needs {}",
                group.desc.entries.len(),
                pipe.n_inputs + pipe.n_outputs
            ))));
        }
        if let Some(kind) = self.fault.as_mut().and_then(|f| f.on_dispatch()) {
            return Err(self.fault_error(kind, "dispatch_workgroups"));
        }
        let e = self.encoder_mut(enc)?;
        e.commands.push(Command::Dispatch { x, y, z });
        self.phase(4, t0);
        Ok(())
    }

    pub fn end_compute_pass(&mut self, enc: CommandEncoderId) -> Result<()> {
        let t0 = Instant::now();
        let e = self.encoder_mut(enc)?;
        if e.state != EncoderState::PassOpen {
            return Err(self.fail(Error::Validation("end_compute_pass without pass".into())));
        }
        e.state = EncoderState::Open;
        self.phase(5, t0);
        Ok(())
    }

    pub fn finish(&mut self, enc: CommandEncoderId) -> Result<CommandBufferId> {
        let t0 = Instant::now();
        let e = self.encoder_mut(enc)?;
        if e.state == EncoderState::PassOpen {
            return Err(self.fail(Error::Validation("finish with open pass".into())));
        }
        if e.state == EncoderState::Finished {
            return Err(self.fail(Error::Validation("finish called twice".into())));
        }
        e.state = EncoderState::Finished;
        let label = e.label.clone();
        let commands = std::mem::take(&mut e.commands);
        self.encoders.remove(&enc);
        let id = CommandBufferId(self.id());
        self.cmdbufs.insert(id, CommandBuffer { label, commands, consumed: false });
        self.phase(6, t0);
        Ok(id)
    }

    // ------------------------------------------------------------- submit --
    /// `queue.submit`: validates, executes every dispatch through the kernel
    /// runner, advances the GPU frontier asynchronously, applies the
    /// profile's submit-floor rate limit.
    pub fn submit(&mut self, bufs: &[CommandBufferId], runner: &dyn KernelRunner) -> Result<()> {
        let t0 = Instant::now();
        // Rate-limit floor (Firefox model): enforce min interval between submits.
        if self.profile.submit_floor_ns > 0 {
            let floor = self.jitter.apply(self.profile.submit_floor_ns, self.profile.jitter_pct);
            let earliest = self.clock.last_submit_ns + floor;
            if self.clock.cpu_ns < earliest {
                self.clock.cpu_ns = earliest;
            }
        }
        self.clock.last_submit_ns = self.clock.cpu_ns;

        for &cb_id in bufs {
            let commands = {
                let cb = self.cmdbufs.get_mut(&cb_id).ok_or_else(|| {
                    Error::InvalidResource(format!("command buffer {cb_id:?}"))
                })?;
                if cb.consumed {
                    return Err(self.fail(Error::Validation(format!(
                        "command buffer {cb_id:?} already submitted"
                    ))));
                }
                cb.consumed = true;
                cb.commands.clone()
            };
            self.execute_commands(&commands, runner)?;
            self.cmdbufs.remove(&cb_id);
        }
        self.stats.submits += 1;
        // Metal-style sequential backpressure: only under back-to-back
        // submission (a sync drains the queue, resetting it) — this is why
        // wgpu/Metal measures 71.1 us sequential but 48.3 us single-op.
        if !self.synced_since_submit {
            let extra =
                self.jitter.apply(self.profile.seq_backpressure_ns, self.profile.jitter_pct);
            self.clock.advance_cpu(extra);
        }
        self.synced_since_submit = false;
        self.phase(7, t0);
        Ok(())
    }

    fn execute_commands(&mut self, commands: &[Command], runner: &dyn KernelRunner) -> Result<()> {
        let mut pipeline: Option<ComputePipelineId> = None;
        let mut group: Option<BindGroupId> = None;
        for cmd in commands {
            match cmd {
                Command::SetPipeline(p) => pipeline = Some(*p),
                Command::SetBindGroup(g) => group = Some(*g),
                Command::Dispatch { .. } => {
                    let p = pipeline.ok_or_else(|| {
                        Error::Validation("dispatch without pipeline at submit".into())
                    })?;
                    let g = group.ok_or_else(|| {
                        Error::Validation("dispatch without bind group at submit".into())
                    })?;
                    self.execute_dispatch(p, g, runner)?;
                }
            }
        }
        Ok(())
    }

    fn execute_dispatch(
        &mut self,
        pipe_id: ComputePipelineId,
        group_id: BindGroupId,
        runner: &dyn KernelRunner,
    ) -> Result<()> {
        let (kernel, in_specs, out_specs) = {
            let pipe = &self.pipelines[&pipe_id];
            let m = &self.modules[&pipe.module];
            (m.desc.kernel.clone(), m.desc.inputs.clone(), m.desc.outputs.clone())
        };
        let entries = self.groups[&group_id].desc.entries.clone();

        // Gather input tensors from bound buffers (submit-time liveness check).
        let mut inputs = Vec::with_capacity(in_specs.len());
        for (i, spec) in in_specs.iter().enumerate() {
            let entry = entries[i];
            let buf = self.buffers.get(&entry.buffer).ok_or_else(|| {
                Error::InvalidResource(format!("buffer {:?} in bind group", entry.buffer))
            })?;
            if buf.destroyed {
                return Err(self.fail(Error::Validation(format!(
                    "buffer {:?} destroyed before submit",
                    entry.buffer
                ))));
            }
            let bytes = &buf.data[entry.offset..entry.offset + entry.size];
            inputs.push(tensor_from_bytes(spec, bytes)?);
        }

        let t_k = Instant::now();
        let (outputs, measured_ns, flops) = runner.run(&kernel, &inputs, &out_specs)?;
        let measured_ns = if measured_ns > 0 {
            measured_ns
        } else {
            t_k.elapsed().as_nanos() as u64
        };
        if outputs.len() != out_specs.len() {
            return Err(Error::Runtime(format!(
                "kernel {kernel}: expected {} outputs, got {}",
                out_specs.len(),
                outputs.len()
            )));
        }

        // Write outputs into the bound output buffers.
        for (j, out) in outputs.iter().enumerate() {
            let spec = &out_specs[j];
            if out.shape != spec.shape {
                return Err(Error::Runtime(format!(
                    "kernel {kernel}: output {j} shape {:?} != spec {:?}",
                    out.shape, spec.shape
                )));
            }
            let entry = entries[in_specs.len() + j];
            let buf = self.buffers.get_mut(&entry.buffer).unwrap();
            let bytes = out.data.as_bytes();
            buf.data[entry.offset..entry.offset + bytes.len()].copy_from_slice(bytes);
        }

        // Advance the GPU frontier.
        const KERNEL_FLOOR_NS: u64 = 3_000; // GPU kernel execution floor
        let kernel_ns = match self.kernel_time_policy {
            KernelTimePolicy::Measured => measured_ns,
            KernelTimePolicy::Calibrated => {
                // Roofline-style: max of the compute-bound and memory-bound
                // times, floored at a few microseconds. Deterministic, so
                // benchmark CV reflects the profile's jitter, not host noise.
                let io_bytes: usize = in_specs.iter().map(|s| s.size_bytes()).sum::<usize>()
                    + out_specs.iter().map(|s| s.size_bytes()).sum::<usize>();
                let t_compute = if self.profile.kernel_gflops > 0.0 {
                    flops / self.profile.kernel_gflops // ns (flops / (GF/s * 1e9) * 1e9)
                } else {
                    0.0
                };
                let t_mem = if self.profile.mem_gbps > 0.0 {
                    io_bytes as f64 / self.profile.mem_gbps // ns
                } else {
                    0.0
                };
                (t_compute.max(t_mem) as u64).max(KERNEL_FLOOR_NS)
            }
        };
        self.clock.enqueue_gpu(kernel_ns);
        self.timeline.kernel_virtual_ns += kernel_ns;
        self.stats.dispatches_executed += 1;
        Ok(())
    }
}

fn tensor_from_bytes(spec: &super::pipeline::KernelIoSpec, bytes: &[u8]) -> Result<Tensor> {
    let n = spec.numel();
    if bytes.len() != n * 4 {
        return Err(Error::Shape(format!(
            "binding holds {} bytes, spec {:?} needs {}",
            bytes.len(),
            spec.shape,
            n * 4
        )));
    }
    match spec.dtype {
        DType::F32 => {
            let mut v = vec![0f32; n];
            for (i, c) in bytes.chunks_exact(4).enumerate() {
                v[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            Tensor::f32(spec.shape.clone(), v)
        }
        DType::I32 => {
            let mut v = vec![0i32; n];
            for (i, c) in bytes.chunks_exact(4).enumerate() {
                v[i] = i32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            Tensor::i32(spec.shape.clone(), v)
        }
    }
}

//! Command encoders, compute passes and command buffers.
//!
//! The encoder records commands; nothing executes until `queue.submit`.
//! Recording still performs real validation work (state checks), and each
//! recording call advances the virtual clock by its calibrated phase cost —
//! encoder creation and `finish` are the #2/#3 contributors after submit in
//! the paper's Table 20 breakdown.



use super::bindgroup::BindGroupId;
use super::pipeline::ComputePipelineId;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CommandEncoderId(pub u64);

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CommandBufferId(pub u64);

/// One recorded command.
#[derive(Debug, Clone)]
pub(crate) enum Command {
    SetPipeline(ComputePipelineId),
    SetBindGroup(BindGroupId),
    // workgroup counts are validated at record time; kept for tooling
    #[allow(dead_code)]
    Dispatch { x: u32, y: u32, z: u32 },
}

/// Encoder state machine: open -> (pass open) -> finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum EncoderState {
    Open,
    PassOpen,
    Finished,
}

#[derive(Debug)]
pub(crate) struct CommandEncoder {
    pub label: String,
    pub state: EncoderState,
    pub commands: Vec<Command>,
    /// Dispatch-time validation state within the current pass.
    pub current_pipeline: Option<ComputePipelineId>,
    pub current_bind_group: Option<BindGroupId>,
}

impl CommandEncoder {
    pub fn new(label: String) -> Self {
        CommandEncoder {
            label,
            state: EncoderState::Open,
            commands: Vec::with_capacity(8),
            current_pipeline: None,
            current_bind_group: None,
        }
    }
}

/// A finished, submittable command buffer.
#[derive(Debug)]
pub(crate) struct CommandBuffer {
    #[allow(dead_code)] // diagnostics
    pub label: String,
    pub commands: Vec<Command>,
    pub consumed: bool,
}

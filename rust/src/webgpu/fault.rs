//! Deterministic fault injection for the dispatch substrate.
//!
//! Real WebGPU deployments must survive the failure modes the paper's
//! validation-heavy dispatch path implies: device loss, allocation
//! failure under memory pressure, and hung readbacks. This module makes
//! every one of them reproducible in CI without a GPU: a [`FaultPlan`]
//! names *which* opportunity fails (the Nth dispatch, the Nth buffer
//! allocation, the Nth coalesced readback), the [`FaultInjector`]
//! counts opportunities as the [`super::device::Device`] reaches them
//! and fires each trigger exactly once.
//!
//! Triggers are **one-shot**, which is what makes injected faults
//! transient: the failed call consumed the trigger, so an identical
//! retry succeeds. Seeded plans ([`FaultPlan::seeded`]) draw only
//! transient kinds — they drive the differential suite's byte-identity
//! arm, which requires every session to recover. Device loss is only
//! ever injected by hand-built plans (it is fatal by definition).

use crate::model::rng::XorShiftRng;

/// What kind of failure a trigger injects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `dispatch_workgroups` fails validation-side after the real
    /// validation checks pass (a spurious device-side rejection).
    /// Transient: the command was never recorded.
    DispatchFail,
    /// `create_buffer` fails as if the allocator were out of memory.
    /// Transient: memory pressure is relieved by eviction/retirement.
    AllocFail,
    /// `map_read_many` times out before the buffers map. Transient: the
    /// buffers still hold their contents, a re-issued map succeeds.
    MapTimeout,
    /// The device is lost. Fatal and device-scoped: once fired, every
    /// subsequent injection checkpoint also fails.
    DeviceLost,
}

/// One injected failure: the `at`-th opportunity (1-based) of the
/// trigger's counter class fails. [`FaultKind::DispatchFail`] and
/// [`FaultKind::DeviceLost`] count dispatch calls, [`FaultKind::AllocFail`]
/// counts buffer creations, [`FaultKind::MapTimeout`] counts coalesced
/// readbacks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultTrigger {
    pub kind: FaultKind,
    pub at: u64,
}

/// A reproducible schedule of fault triggers.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub triggers: Vec<FaultTrigger>,
}

impl FaultPlan {
    pub fn new(triggers: Vec<FaultTrigger>) -> Self {
        FaultPlan { triggers }
    }

    /// Derive a transient-only plan from a seed: 2–4 triggers, biased
    /// toward dispatch failures (the plentiful opportunity class —
    /// hundreds per serving run), with allocation failures and map
    /// timeouts placed early where their opportunity counters actually
    /// reach (steady-state pool reuse means `create_buffer` is rare).
    /// Never draws [`FaultKind::DeviceLost`]: seeded plans drive the
    /// byte-identity differential arm, which requires recovery.
    pub fn seeded(seed: u64) -> Self {
        let mut rng =
            XorShiftRng::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xFA71);
        let n = 2 + rng.below(3); // 2..=4 triggers
        let mut triggers = Vec::with_capacity(n);
        for _ in 0..n {
            let t = match rng.below(4) {
                0 | 1 => FaultTrigger {
                    kind: FaultKind::DispatchFail,
                    at: 1 + rng.below(1500) as u64,
                },
                2 => FaultTrigger {
                    kind: FaultKind::MapTimeout,
                    at: 1 + rng.below(30) as u64,
                },
                _ => FaultTrigger {
                    kind: FaultKind::AllocFail,
                    at: 1 + rng.below(40) as u64,
                },
            };
            triggers.push(t);
        }
        FaultPlan { triggers }
    }
}

/// Counts fault opportunities and fires the plan's triggers. Installed
/// on a [`super::device::Device`] via `install_fault_injector`; the
/// device consults `on_dispatch`/`on_alloc`/`on_map` at each
/// opportunity and converts a returned kind into the matching typed
/// error.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    plan: FaultPlan,
    fired: Vec<bool>,
    dispatch_calls: u64,
    alloc_calls: u64,
    map_calls: u64,
    injected: u64,
    lost: bool,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        let n = plan.triggers.len();
        FaultInjector {
            plan,
            fired: vec![false; n],
            dispatch_calls: 0,
            alloc_calls: 0,
            map_calls: 0,
            injected: 0,
            lost: false,
        }
    }

    /// Faults fired so far (observability: `ServeReport.faults_injected`).
    pub fn injected(&self) -> u64 {
        self.injected
    }

    /// Whether a `DeviceLost` trigger has fired (latched).
    pub fn device_lost(&self) -> bool {
        self.lost
    }

    fn check(&mut self, calls: u64, kinds: &[FaultKind]) -> Option<FaultKind> {
        if self.lost {
            return Some(FaultKind::DeviceLost);
        }
        for (i, t) in self.plan.triggers.iter().enumerate() {
            if !self.fired[i] && t.at == calls && kinds.contains(&t.kind) {
                self.fired[i] = true;
                self.injected += 1;
                if t.kind == FaultKind::DeviceLost {
                    self.lost = true;
                }
                return Some(t.kind);
            }
        }
        None
    }

    /// A dispatch opportunity (also the counter class for device loss).
    pub fn on_dispatch(&mut self) -> Option<FaultKind> {
        self.dispatch_calls += 1;
        let calls = self.dispatch_calls;
        self.check(calls, &[FaultKind::DispatchFail, FaultKind::DeviceLost])
    }

    /// A buffer-allocation opportunity.
    pub fn on_alloc(&mut self) -> Option<FaultKind> {
        self.alloc_calls += 1;
        let calls = self.alloc_calls;
        self.check(calls, &[FaultKind::AllocFail])
    }

    /// A coalesced-readback opportunity.
    pub fn on_map(&mut self) -> Option<FaultKind> {
        self.map_calls += 1;
        let calls = self.map_calls;
        self.check(calls, &[FaultKind::MapTimeout])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triggers_fire_once_at_their_opportunity() {
        let mut inj = FaultInjector::new(FaultPlan::new(vec![FaultTrigger {
            kind: FaultKind::DispatchFail,
            at: 3,
        }]));
        assert_eq!(inj.on_dispatch(), None);
        assert_eq!(inj.on_dispatch(), None);
        assert_eq!(inj.on_dispatch(), Some(FaultKind::DispatchFail));
        // One-shot: the retry of the same opportunity class succeeds.
        assert_eq!(inj.on_dispatch(), None);
        assert_eq!(inj.injected(), 1);
    }

    #[test]
    fn counter_classes_are_independent() {
        let mut inj = FaultInjector::new(FaultPlan::new(vec![
            FaultTrigger { kind: FaultKind::AllocFail, at: 1 },
            FaultTrigger { kind: FaultKind::MapTimeout, at: 2 },
        ]));
        // Dispatch opportunity 1 does not fire the alloc trigger.
        assert_eq!(inj.on_dispatch(), None);
        assert_eq!(inj.on_alloc(), Some(FaultKind::AllocFail));
        assert_eq!(inj.on_map(), None);
        assert_eq!(inj.on_map(), Some(FaultKind::MapTimeout));
        assert_eq!(inj.injected(), 2);
    }

    #[test]
    fn device_loss_latches() {
        let mut inj = FaultInjector::new(FaultPlan::new(vec![FaultTrigger {
            kind: FaultKind::DeviceLost,
            at: 2,
        }]));
        assert_eq!(inj.on_dispatch(), None);
        assert_eq!(inj.on_dispatch(), Some(FaultKind::DeviceLost));
        assert!(inj.device_lost());
        // Every subsequent opportunity of every class fails too.
        assert_eq!(inj.on_dispatch(), Some(FaultKind::DeviceLost));
        assert_eq!(inj.on_alloc(), Some(FaultKind::DeviceLost));
        assert_eq!(inj.on_map(), Some(FaultKind::DeviceLost));
    }

    #[test]
    fn seeded_plans_are_deterministic_and_transient_only() {
        let a = FaultPlan::seeded(42);
        let b = FaultPlan::seeded(42);
        assert_eq!(a.triggers, b.triggers);
        assert!((2..=4).contains(&a.triggers.len()));
        for t in &a.triggers {
            assert_ne!(t.kind, FaultKind::DeviceLost, "seeded plans must be recoverable");
            assert!(t.at >= 1, "opportunity indices are 1-based");
        }
        // Different seeds diverge (probabilistically; these two do).
        let c = FaultPlan::seeded(43);
        assert_ne!(a.triggers, c.triggers);
    }
}

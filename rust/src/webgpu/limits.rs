//! Device limits — validated on every resource creation and dispatch, the
//! way a WebGPU implementation enforces its `GPUSupportedLimits`.



#[derive(Debug, Clone)]
pub struct Limits {
    pub max_buffer_size: usize,
    pub max_bind_groups: usize,
    pub max_bindings_per_group: usize,
    pub max_compute_workgroups_per_dimension: u32,
    pub max_compute_invocations_per_workgroup: u32,
    pub max_storage_buffer_binding_size: usize,
}

impl Default for Limits {
    /// WebGPU spec defaults (approximately — the values browsers guarantee).
    fn default() -> Self {
        Limits {
            max_buffer_size: 256 << 20,              // 256 MiB
            max_bind_groups: 4,
            max_bindings_per_group: 8,
            max_compute_workgroups_per_dimension: 65_535,
            max_compute_invocations_per_workgroup: 256,
            max_storage_buffer_binding_size: 128 << 20,
        }
    }
}

impl Limits {
    /// A deliberately tiny limit set for failure-injection tests.
    pub fn tiny() -> Self {
        Limits {
            max_buffer_size: 1 << 10,
            max_bind_groups: 1,
            max_bindings_per_group: 2,
            max_compute_workgroups_per_dimension: 4,
            max_compute_invocations_per_workgroup: 16,
            max_storage_buffer_binding_size: 1 << 10,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_spec_shaped() {
        let l = Limits::default();
        assert_eq!(l.max_compute_workgroups_per_dimension, 65_535);
        assert!(l.max_buffer_size >= l.max_storage_buffer_binding_size);
    }
}

//! The WebGPU-shaped dispatch substrate.
//!
//! This is the substitution for Dawn / wgpu-native / browser WebGPU (the
//! paper's subject): a command-buffer API with **real per-call validation**
//! (usage flags, bind-group compatibility, bounds, limits) and the same call
//! sequence the paper instruments (Table 20):
//!
//! ```text
//! encoder create -> pass begin -> set pipeline -> set bind group ->
//! dispatch -> pass end -> encoder finish -> queue submit -> (sync)
//! ```
//!
//! Every call does real work under the wall clock *and* advances a virtual
//! clock by the calibrated per-phase cost of the selected
//! [`profile::ImplementationProfile`] (Dawn/Vulkan, wgpu/Vulkan, wgpu/Metal,
//! Chrome, Safari, Firefox — constants from the paper's Tables 6 and 20).
//! Submission is asynchronous in the model exactly as in WebGPU: the GPU
//! completion frontier advances independently of CPU time, which is what
//! makes single-op benchmarks conflate sync and overestimate per-dispatch
//! cost by ~20x (the paper's headline methodology finding).

pub mod bindgroup;
pub mod buffer;
pub mod clock;
pub mod device;
pub mod encoder;
pub mod fault;
pub mod limits;
pub mod pipeline;
pub mod pool;
pub mod profile;
pub mod queue;
pub mod validation;

pub use bindgroup::{BindGroupDesc, BindGroupId, BindGroupLayoutDesc, BindGroupLayoutId, BindingType};
pub use buffer::{BufferDesc, BufferId, BufferUsage};
pub use clock::{PhaseTimeline, VirtualClock, DISPATCH_PHASES};
pub use device::{Device, KernelRunner, NullRunner};
pub use encoder::{CommandBufferId, CommandEncoderId};
pub use fault::{FaultInjector, FaultKind, FaultPlan, FaultTrigger};
pub use limits::Limits;
pub use pipeline::{ComputePipelineId, KernelIoSpec, ShaderModuleDesc, ShaderModuleId};
pub use pool::{BufferPool, PoolStats};
pub use profile::{Backend, ImplementationProfile, Platform};

//! Shader modules and compute pipelines.
//!
//! In the real system a shader module holds WGSL; here it holds the name of
//! an AOT-compiled Pallas kernel (an `artifacts/k_*.hlo.txt` module) plus
//! its I/O signature. Pipeline creation validates the layout against the
//! kernel signature — the analogue of WGSL binding-interface validation at
//! `createComputePipeline` time.



use super::bindgroup::BindGroupLayoutId;
use crate::tensor::DType;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShaderModuleId(pub u64);

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ComputePipelineId(pub u64);

/// Shape + dtype of one kernel input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelIoSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl KernelIoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.numel() * self.dtype.size_bytes()
    }
}

/// "WGSL source" of the module: the kernel it names + its signature.
#[derive(Debug, Clone)]
pub struct ShaderModuleDesc {
    pub label: String,
    /// Registry name of the AOT kernel (e.g. "rmsnorm_64").
    pub kernel: String,
    pub inputs: Vec<KernelIoSpec>,
    pub outputs: Vec<KernelIoSpec>,
}

#[derive(Debug, Clone)]
pub(crate) struct ShaderModule {
    pub desc: ShaderModuleDesc,
}

#[derive(Debug, Clone)]
pub(crate) struct ComputePipeline {
    #[allow(dead_code)] // diagnostics
    pub label: String,
    pub module: ShaderModuleId,
    pub layout: BindGroupLayoutId,
    /// Cached from the module for dispatch-time checks.
    pub n_inputs: usize,
    pub n_outputs: usize,
}

//! Bounded size-class buffer pool.
//!
//! The eager executor's activation buffers cycle through here (the
//! paper's buffer-pooling experiment — re-creating buffers per dispatch
//! is purely hostile). The pool is **bounded**: it tracks outstanding and
//! high-water bytes, and past a configurable byte cap it errors instead
//! of growing silently, so a leak (buffers acquired and never released)
//! surfaces as a `LimitExceeded` rather than unbounded device memory.
//! Before erroring, an over-cap acquire first evicts free-listed (idle)
//! buffers — largest size class first, oldest within a class — so
//! transient pressure from mixed size classes resolves itself instead
//! of aborting the serving round. Only if the free lists cannot make
//! room does the acquire fail. Stats (including evictions) are exported
//! into the serving report.

use std::collections::HashMap;

use super::buffer::{BufferDesc, BufferId, BufferUsage};
use super::device::Device;
use crate::{Error, Result};

/// Pool counters, all in bytes unless noted.
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolStats {
    /// Buffers created through the pool (count).
    pub created: u64,
    /// Acquisitions served from the free list (count).
    pub reused: u64,
    /// Bytes currently acquired and not yet released.
    pub outstanding_bytes: usize,
    /// Peak of `outstanding_bytes` over the pool's lifetime.
    pub high_water_bytes: usize,
    /// Total bytes of every buffer the pool has ever created (outstanding
    /// + free-listed) — the quantity the cap bounds.
    pub total_bytes: usize,
    /// Free-listed buffers destroyed to make room for an over-cap
    /// acquire (count).
    pub evictions: u64,
}

pub struct BufferPool {
    free: HashMap<usize, Vec<BufferId>>,
    cap_bytes: Option<usize>,
    stats: PoolStats,
}

impl BufferPool {
    pub fn new(cap_bytes: Option<usize>) -> Self {
        BufferPool { free: HashMap::new(), cap_bytes, stats: PoolStats::default() }
    }

    pub fn set_cap(&mut self, cap_bytes: Option<usize>) {
        self.cap_bytes = cap_bytes;
    }

    pub fn cap_bytes(&self) -> Option<usize> {
        self.cap_bytes
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// Acquire a buffer of exactly `size` bytes: free-list reuse first,
    /// otherwise a fresh allocation — which errors past the cap.
    pub fn acquire(&mut self, device: &mut Device, size: usize) -> Result<BufferId> {
        if let Some(free) = self.free.get_mut(&size) {
            if let Some(b) = free.pop() {
                self.stats.reused += 1;
                self.stats.outstanding_bytes += size;
                self.stats.high_water_bytes =
                    self.stats.high_water_bytes.max(self.stats.outstanding_bytes);
                return Ok(b);
            }
        }
        self.create_buffer(device, size)
    }

    /// Allocate a fresh `size`-byte buffer through the cap: re-probe the
    /// exact-size free list first (a same-size idle buffer must be reused,
    /// never evicted around), then evict other idle classes if the cap
    /// demands it, then create. `acquire` funnels here after its own
    /// free-list check; the re-probe keeps direct callers from churning —
    /// without it, an over-cap `create_buffer` would destroy the largest
    /// idle class even when an exact-size buffer sits idle.
    pub fn create_buffer(&mut self, device: &mut Device, size: usize) -> Result<BufferId> {
        if let Some(free) = self.free.get_mut(&size) {
            if let Some(b) = free.pop() {
                self.stats.reused += 1;
                self.stats.outstanding_bytes += size;
                self.stats.high_water_bytes =
                    self.stats.high_water_bytes.max(self.stats.outstanding_bytes);
                return Ok(b);
            }
        }
        if let Some(cap) = self.cap_bytes {
            if self.stats.total_bytes + size > cap {
                self.evict_lru(device, size, cap)?;
            }
            if self.stats.total_bytes + size > cap {
                return Err(Error::LimitExceeded(format!(
                    "buffer pool cap {cap} B exceeded: {} B held, {size} B requested",
                    self.stats.total_bytes
                )));
            }
        }
        let b = device.create_buffer(BufferDesc {
            label: format!("pool-{size}"),
            size,
            usage: BufferUsage::STORAGE
                | BufferUsage::COPY_DST
                | BufferUsage::COPY_SRC
                | BufferUsage::MAP_READ,
        })?;
        self.stats.created += 1;
        self.stats.total_bytes += size;
        self.stats.outstanding_bytes += size;
        self.stats.high_water_bytes =
            self.stats.high_water_bytes.max(self.stats.outstanding_bytes);
        Ok(b)
    }

    /// Destroy idle (free-listed) buffers until `size` more bytes fit
    /// under `cap`, or the free lists run dry. Deterministic order —
    /// largest size class first, and within a class the oldest (front
    /// of the list, LRU: `release` pushes to the back) — so twin runs
    /// evict identically. The requested class's own free list is
    /// necessarily empty here (a free-list hit returns before the cap
    /// check), so eviction only ever reclaims *other* classes.
    fn evict_lru(&mut self, device: &mut Device, size: usize, cap: usize) -> Result<()> {
        let mut classes: Vec<usize> = self
            .free
            .iter()
            .filter(|(_, v)| !v.is_empty())
            .map(|(&s, _)| s)
            .collect();
        classes.sort_unstable_by(|a, b| b.cmp(a));
        'outer: for class in classes {
            while self.stats.total_bytes + size > cap {
                let Some(list) = self.free.get_mut(&class) else { break };
                if list.is_empty() {
                    break;
                }
                let id = list.remove(0);
                device.destroy_buffer(id)?;
                self.stats.total_bytes = self.stats.total_bytes.saturating_sub(class);
                self.stats.evictions += 1;
            }
            if self.stats.total_bytes + size <= cap {
                break 'outer;
            }
        }
        Ok(())
    }

    /// Return a buffer of `size` bytes to the free list.
    pub fn release(&mut self, size: usize, id: BufferId) {
        self.stats.outstanding_bytes = self.stats.outstanding_bytes.saturating_sub(size);
        self.free.entry(size).or_default().push(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::webgpu::ImplementationProfile;

    fn device() -> Device {
        Device::new(ImplementationProfile::zero_overhead())
    }

    #[test]
    fn reuses_before_creating() {
        let mut d = device();
        let mut p = BufferPool::new(None);
        let a = p.acquire(&mut d, 256).unwrap();
        p.release(256, a);
        let b = p.acquire(&mut d, 256).unwrap();
        assert_eq!(a, b, "free-listed buffer must be reused");
        let s = p.stats();
        assert_eq!(s.created, 1);
        assert_eq!(s.reused, 1);
        assert_eq!(s.total_bytes, 256);
    }

    #[test]
    fn tracks_outstanding_and_high_water() {
        let mut d = device();
        let mut p = BufferPool::new(None);
        let a = p.acquire(&mut d, 100).unwrap();
        let b = p.acquire(&mut d, 200).unwrap();
        assert_eq!(p.stats().outstanding_bytes, 300);
        assert_eq!(p.stats().high_water_bytes, 300);
        p.release(100, a);
        p.release(200, b);
        assert_eq!(p.stats().outstanding_bytes, 0);
        assert_eq!(p.stats().high_water_bytes, 300, "high-water is sticky");
    }

    #[test]
    fn cap_errors_instead_of_growing() {
        let mut d = device();
        let mut p = BufferPool::new(Some(256));
        let a = p.acquire(&mut d, 200).unwrap();
        let err = p.acquire(&mut d, 100);
        assert!(
            matches!(err, Err(Error::LimitExceeded(_))),
            "over-cap acquire with no idle buffers must error, got {err:?}"
        );
        assert_eq!(p.stats().evictions, 0, "nothing idle to evict");
        // Reuse within the cap still works.
        p.release(200, a);
        assert!(p.acquire(&mut d, 200).is_ok());
    }

    #[test]
    fn over_cap_acquire_evicts_idle_buffers_before_erroring() {
        let mut d = device();
        let mut p = BufferPool::new(Some(512));
        // Fill the cap with two idle classes: 2x128 free-listed + 256 held.
        let a = p.acquire(&mut d, 128).unwrap();
        let b = p.acquire(&mut d, 128).unwrap();
        let _held = p.acquire(&mut d, 256).unwrap();
        p.release(128, a);
        p.release(128, b);
        assert_eq!(p.stats().total_bytes, 512);
        // A 200 B acquire does not fit (512 + 200 > 512) but the idle
        // 128 B buffers can be evicted: two evictions free 256 B.
        let c = p.acquire(&mut d, 200);
        assert!(c.is_ok(), "eviction must make room, got {c:?}");
        let s = p.stats();
        assert_eq!(s.evictions, 2, "both idle 128 B buffers evicted");
        assert_eq!(s.total_bytes, 512 - 256 + 200);
        // The evicted buffers are gone from the device, not leaked into
        // the free lists: a fresh 128 B acquire (after parking the 200 B
        // buffer, which eviction then reclaims) creates a new buffer.
        let before = s.created;
        p.release(200, c.unwrap());
        let _ = p.acquire(&mut d, 128).unwrap();
        let s = p.stats();
        assert_eq!(s.created, before + 1);
        assert_eq!(s.evictions, 3, "the idle 200 B buffer was reclaimed too");
    }

    #[test]
    fn eviction_order_is_deterministic_largest_class_first() {
        let mut d = device();
        let mut p = BufferPool::new(Some(1024));
        let big = p.acquire(&mut d, 512).unwrap();
        let small = p.acquire(&mut d, 128).unwrap();
        p.release(512, big);
        p.release(128, small);
        // Needs 384 freed: the 512 B class (largest first) alone covers it.
        assert!(p.acquire(&mut d, 768).is_ok());
        let s = p.stats();
        assert_eq!(s.evictions, 1, "one eviction from the largest class suffices");
        // The small class survived and is still reusable.
        let before = s.created;
        let again = p.acquire(&mut d, 128).unwrap();
        assert_eq!(again, small);
        assert_eq!(p.stats().created, before);
    }

    #[test]
    fn pool_evictions() {
        // Regression: an over-cap `create_buffer` must prefer exact-size
        // free-list reuse over evicting the largest idle class. Before the
        // re-probe, a direct `create_buffer(512)` at a full cap destroyed
        // the idle 512 B buffer (largest class) and created a new one —
        // one pointless eviction plus one pointless creation.
        let mut d = device();
        let mut p = BufferPool::new(Some(1024));
        let big = p.acquire(&mut d, 512).unwrap();
        let small = p.acquire(&mut d, 256).unwrap();
        p.release(512, big);
        p.release(256, small);
        assert_eq!(p.stats().total_bytes, 768);
        // Cap is 1024; a fresh 512 would overflow (768 + 512 > 1024), but
        // an exact-size 512 B buffer is idle: it must be reused, with zero
        // evictions and zero new creations.
        let again = p.create_buffer(&mut d, 512).unwrap();
        assert_eq!(again, big, "exact-size idle buffer must be reused");
        let s = p.stats();
        assert_eq!(s.evictions, 0, "no eviction when a same-size buffer is free");
        assert_eq!(s.created, 2, "no new buffer created");
        assert_eq!(s.reused, 1);
        // With the exact class empty, over-cap creation still evicts
        // other idle classes (here the 256 B one) before erroring.
        let other = p.create_buffer(&mut d, 512).unwrap();
        assert_ne!(other, again);
        let s = p.stats();
        assert_eq!(s.evictions, 1, "idle 256 B class evicted to make room");
        assert_eq!(s.created, 3);
    }

    #[test]
    fn distinct_size_classes_do_not_mix() {
        let mut d = device();
        let mut p = BufferPool::new(None);
        let a = p.acquire(&mut d, 64).unwrap();
        p.release(64, a);
        let b = p.acquire(&mut d, 128).unwrap();
        assert_ne!(a, b);
        assert_eq!(p.stats().created, 2);
    }
}

//! Bounded size-class buffer pool.
//!
//! The eager executor's activation buffers cycle through here (the
//! paper's buffer-pooling experiment — re-creating buffers per dispatch
//! is purely hostile). The pool is **bounded**: it tracks outstanding and
//! high-water bytes, and past a configurable byte cap it errors instead
//! of growing silently, so a leak (buffers acquired and never released)
//! surfaces as a `LimitExceeded` rather than unbounded device memory.
//! Stats are exported into the serving report.

use std::collections::HashMap;

use super::buffer::{BufferDesc, BufferId, BufferUsage};
use super::device::Device;
use crate::{Error, Result};

/// Pool counters, all in bytes unless noted.
#[derive(Debug, Clone, Copy, Default)]
pub struct PoolStats {
    /// Buffers created through the pool (count).
    pub created: u64,
    /// Acquisitions served from the free list (count).
    pub reused: u64,
    /// Bytes currently acquired and not yet released.
    pub outstanding_bytes: usize,
    /// Peak of `outstanding_bytes` over the pool's lifetime.
    pub high_water_bytes: usize,
    /// Total bytes of every buffer the pool has ever created (outstanding
    /// + free-listed) — the quantity the cap bounds.
    pub total_bytes: usize,
}

pub struct BufferPool {
    free: HashMap<usize, Vec<BufferId>>,
    cap_bytes: Option<usize>,
    stats: PoolStats,
}

impl BufferPool {
    pub fn new(cap_bytes: Option<usize>) -> Self {
        BufferPool { free: HashMap::new(), cap_bytes, stats: PoolStats::default() }
    }

    pub fn set_cap(&mut self, cap_bytes: Option<usize>) {
        self.cap_bytes = cap_bytes;
    }

    pub fn cap_bytes(&self) -> Option<usize> {
        self.cap_bytes
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// Acquire a buffer of exactly `size` bytes: free-list reuse first,
    /// otherwise a fresh allocation — which errors past the cap.
    pub fn acquire(&mut self, device: &mut Device, size: usize) -> Result<BufferId> {
        if let Some(free) = self.free.get_mut(&size) {
            if let Some(b) = free.pop() {
                self.stats.reused += 1;
                self.stats.outstanding_bytes += size;
                self.stats.high_water_bytes =
                    self.stats.high_water_bytes.max(self.stats.outstanding_bytes);
                return Ok(b);
            }
        }
        if let Some(cap) = self.cap_bytes {
            if self.stats.total_bytes + size > cap {
                return Err(Error::LimitExceeded(format!(
                    "buffer pool cap {cap} B exceeded: {} B held, {size} B requested",
                    self.stats.total_bytes
                )));
            }
        }
        let b = device.create_buffer(BufferDesc {
            label: format!("pool-{size}"),
            size,
            usage: BufferUsage::STORAGE
                | BufferUsage::COPY_DST
                | BufferUsage::COPY_SRC
                | BufferUsage::MAP_READ,
        })?;
        self.stats.created += 1;
        self.stats.total_bytes += size;
        self.stats.outstanding_bytes += size;
        self.stats.high_water_bytes =
            self.stats.high_water_bytes.max(self.stats.outstanding_bytes);
        Ok(b)
    }

    /// Return a buffer of `size` bytes to the free list.
    pub fn release(&mut self, size: usize, id: BufferId) {
        self.stats.outstanding_bytes = self.stats.outstanding_bytes.saturating_sub(size);
        self.free.entry(size).or_default().push(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::webgpu::ImplementationProfile;

    fn device() -> Device {
        Device::new(ImplementationProfile::zero_overhead())
    }

    #[test]
    fn reuses_before_creating() {
        let mut d = device();
        let mut p = BufferPool::new(None);
        let a = p.acquire(&mut d, 256).unwrap();
        p.release(256, a);
        let b = p.acquire(&mut d, 256).unwrap();
        assert_eq!(a, b, "free-listed buffer must be reused");
        let s = p.stats();
        assert_eq!(s.created, 1);
        assert_eq!(s.reused, 1);
        assert_eq!(s.total_bytes, 256);
    }

    #[test]
    fn tracks_outstanding_and_high_water() {
        let mut d = device();
        let mut p = BufferPool::new(None);
        let a = p.acquire(&mut d, 100).unwrap();
        let b = p.acquire(&mut d, 200).unwrap();
        assert_eq!(p.stats().outstanding_bytes, 300);
        assert_eq!(p.stats().high_water_bytes, 300);
        p.release(100, a);
        p.release(200, b);
        assert_eq!(p.stats().outstanding_bytes, 0);
        assert_eq!(p.stats().high_water_bytes, 300, "high-water is sticky");
    }

    #[test]
    fn cap_errors_instead_of_growing() {
        let mut d = device();
        let mut p = BufferPool::new(Some(256));
        let a = p.acquire(&mut d, 200).unwrap();
        let err = p.acquire(&mut d, 100);
        assert!(
            matches!(err, Err(Error::LimitExceeded(_))),
            "over-cap acquire must error, got {err:?}"
        );
        // Reuse within the cap still works.
        p.release(200, a);
        assert!(p.acquire(&mut d, 200).is_ok());
    }

    #[test]
    fn distinct_size_classes_do_not_mix() {
        let mut d = device();
        let mut p = BufferPool::new(None);
        let a = p.acquire(&mut d, 64).unwrap();
        p.release(64, a);
        let b = p.acquire(&mut d, 128).unwrap();
        assert_ne!(a, b);
        assert_eq!(p.stats().created, 2);
    }
}

//! Calibrated implementation/backend cost profiles.
//!
//! The paper characterizes five WebGPU implementations (Dawn, wgpu-native,
//! Chrome, Safari, Firefox) over three backends (Vulkan, Metal, D3D12) on
//! four GPU vendors. We cannot run that hardware here, so each configuration
//! becomes a **calibrated cost profile**: per-phase CPU costs whose total
//! equals the paper's *sequential* per-dispatch measurement (Table 6), a
//! per-dispatch synchronization cost that reproduces the *single-op*
//! measurement (sync conflation — the ~20x overestimate), an optional
//! Metal-style sequential backpressure term, and an optional Firefox-style
//! submit rate-limit floor. Phase proportions follow Table 20.
//!
//! The substrate still does real validation/encoding work under the wall
//! clock; the profile only drives the *virtual* clock that regenerates the
//! paper's tables deterministically.



/// Native GPU API under the WebGPU implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Vulkan,
    Metal,
    D3D12,
    /// Not a WebGPU backend — used for the CUDA comparison profile (Table 17).
    Cuda,
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Vulkan => write!(f, "Vulkan"),
            Backend::Metal => write!(f, "Metal"),
            Backend::D3D12 => write!(f, "D3D12"),
            Backend::Cuda => write!(f, "CUDA"),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Platform {
    Linux,
    Windows,
    Macos,
}

/// Per-phase CPU costs of one dispatch, nanoseconds, in Table 20 order:
/// encoder_create, pass_begin, set_pipeline, set_bind_group, dispatch_call,
/// pass_end, encoder_finish, submit.
#[derive(Debug, Clone, Copy)]
pub struct PhaseCosts(pub [u64; 8]);

impl PhaseCosts {
    /// Split `total_ns` across phases using Table 20's measured proportions
    /// (wgpu/Vulkan: 6.4 / 3.2 / 1.4 / 1.0 / 0.6 / 0.7 / 6.1 / 12.9 of
    /// 32.5 us total — submit dominates at ~40%).
    pub fn from_total(total_ns: u64) -> Self {
        const WEIGHTS: [f64; 8] = [6.4, 3.2, 1.4, 1.0, 0.6, 0.7, 6.1, 12.9];
        const SUM: f64 = 32.3;
        let mut phases = [0u64; 8];
        let mut acc = 0u64;
        for i in 0..7 {
            phases[i] = ((total_ns as f64) * WEIGHTS[i] / SUM).round() as u64;
            acc += phases[i];
        }
        phases[7] = total_ns.saturating_sub(acc); // exact total preserved
        PhaseCosts(phases)
    }

    pub fn total(&self) -> u64 {
        self.0.iter().sum()
    }
}

/// One (implementation, backend, device) configuration from Table 6.
#[derive(Debug, Clone)]
pub struct ImplementationProfile {
    /// e.g. "Dawn (RTX 5090)".
    pub name: &'static str,
    /// Implementation family: "dawn", "wgpu", "chrome", "safari", "firefox".
    pub implementation: &'static str,
    pub backend: Backend,
    pub platform: Platform,
    pub is_browser: bool,
    /// Per-phase CPU costs (sum = sequential per-dispatch cost).
    pub phases: PhaseCosts,
    /// Per-dispatch GPU-CPU synchronization cost paid when the host blocks
    /// (map_async wait / onSubmittedWorkDone). In a single-op benchmark this
    /// is paid per dispatch — the conflation the paper quantifies.
    pub sync_ns: u64,
    /// Extra per-dispatch cost that appears only under sustained sequential
    /// submission (observed on wgpu/Metal: sequential 71.1 us > single-op
    /// 48.3 us — command-buffer backpressure).
    pub seq_backpressure_ns: u64,
    /// Minimum virtual time between consecutive queue submits (Firefox's
    /// ~1040 us behavior, consistent with rate-limiting).
    pub submit_floor_ns: u64,
    /// Fixed cost of mapping a buffer for readback (Vulkan ~0.1 ms,
    /// Metal ~1.8 ms — Appendix H).
    pub map_fixed_ns: u64,
    /// Per-byte readback cost (ns/byte).
    pub map_per_byte_ns: f64,
    /// Relative jitter applied to every virtual cost (drives CV/CI).
    pub jitter_pct: f64,
    /// Effective throughput of the unoptimized WGSL kernels on this device
    /// (GFLOP/s) — used for calibrated kernel-time models (Table 8 measured
    /// 1.2-2.1 TFLOP/s on RTX 5090 at production dims).
    pub kernel_gflops: f64,
    /// Effective memory bandwidth (GB/s) for the calibrated kernel-time
    /// model's memory-bound branch (elementwise ops).
    pub mem_gbps: f64,
}

const US: u64 = 1_000;

impl ImplementationProfile {
    fn base(
        name: &'static str,
        implementation: &'static str,
        backend: Backend,
        platform: Platform,
        is_browser: bool,
        seq_us: f64,
        single_us: f64,
        kernel_gflops: f64,
    ) -> Self {
        // dispatch cost = min(seq, single); the difference is either sync
        // (single > seq: conflation) or backpressure (seq > single: Metal).
        let dispatch_us = seq_us.min(single_us);
        let sync_us = (single_us - seq_us).max(0.0);
        let backpressure_us = (seq_us - single_us).max(0.0);
        ImplementationProfile {
            name,
            implementation,
            backend,
            platform,
            is_browser,
            phases: PhaseCosts::from_total((dispatch_us * US as f64) as u64),
            sync_ns: (sync_us * US as f64) as u64,
            seq_backpressure_ns: (backpressure_us * US as f64) as u64,
            submit_floor_ns: 0,
            map_fixed_ns: match backend {
                Backend::Metal => 1_600 * US,
                Backend::Cuda => 10 * US,
                _ => 100 * US,
            },
            map_per_byte_ns: 0.53e0 * 1e-3 * 1e3, // ~0.53 ns/B (fits 0.42 ms / 607 KB)
            jitter_pct: 0.03,
            kernel_gflops,
            // Effective bandwidth scales with the device class; a coarse
            // 0.4 GB/s per GFLOP/s tracks the unoptimized-WGSL regime.
            mem_gbps: (kernel_gflops * 0.4).max(20.0),
        }
    }

    // ---- native implementations (Table 6, top block) ----
    pub fn dawn_vulkan_rtx5090() -> Self {
        Self::base("Dawn (RTX 5090)", "dawn", Backend::Vulkan, Platform::Linux,
                   false, 23.8, 496.8, 2000.0)
    }

    pub fn wgpu_vulkan_rtx5090() -> Self {
        Self::base("wgpu (RTX 5090)", "wgpu", Backend::Vulkan, Platform::Linux,
                   false, 35.8, 35.8, 2000.0)
    }

    pub fn wgpu_vulkan_amd_igpu() -> Self {
        Self::base("wgpu (AMD iGPU)", "wgpu", Backend::Vulkan, Platform::Linux,
                   false, 24.5, 24.8, 250.0)
    }

    pub fn wgpu_metal_m2() -> Self {
        Self::base("wgpu (Apple M2)", "wgpu", Backend::Metal, Platform::Macos,
                   false, 71.1, 48.3, 450.0)
    }

    // ---- browsers, practical (Table 6, middle block) ----
    pub fn chrome_vulkan_rtx5090() -> Self {
        Self::base("Chrome (RTX 5090, Linux)", "chrome", Backend::Vulkan,
                   Platform::Linux, true, 32.8, 2071.2, 1800.0)
    }

    pub fn chrome_d3d12_rtx2000() -> Self {
        Self::base("Chrome (RTX 2000, Win)", "chrome", Backend::D3D12,
                   Platform::Windows, true, 58.7, 2728.8, 700.0)
    }

    pub fn chrome_d3d12_intel() -> Self {
        Self::base("Chrome (Intel iGPU, Win)", "chrome", Backend::D3D12,
                   Platform::Windows, true, 66.5, 3123.6, 180.0)
    }

    pub fn safari_metal_m2() -> Self {
        Self::base("Safari (Apple M2)", "safari", Backend::Metal,
                   Platform::Macos, true, 31.7, 248.0, 450.0)
    }

    // ---- browsers, rate-limited (Table 6, bottom block) ----
    fn firefox(name: &'static str, backend: Backend, platform: Platform,
               seq_us: f64, single_us: f64) -> Self {
        // Base dispatch work resembles other browsers (~35 us); the floor
        // dominates sequential cost; single-op additionally pays huge sync.
        let mut p = Self::base(name, "firefox", backend, platform, true,
                               35.0, 35.0, 400.0);
        p.submit_floor_ns = (seq_us * US as f64) as u64;
        p.sync_ns = ((single_us - seq_us) * US as f64) as u64;
        p
    }

    pub fn firefox_metal_m2() -> Self {
        Self::firefox("Firefox (Apple M2)", Backend::Metal, Platform::Macos,
                      1038.7, 103_490.0)
    }

    pub fn firefox_d3d12_rtx2000() -> Self {
        Self::firefox("Firefox (RTX 2000, Win)", Backend::D3D12,
                      Platform::Windows, 1036.7, 106_520.0)
    }

    pub fn firefox_d3d12_intel() -> Self {
        Self::firefox("Firefox (Intel, Win)", Backend::D3D12,
                      Platform::Windows, 1047.3, 104_328.0)
    }

    // ---- non-WebGPU comparison (Table 17) ----
    pub fn cuda_rtx5090() -> Self {
        // CUDA kernel launch 7.4 +/- 9.2 us (paper Appendix J); high relative
        // jitter reflects the reported variance.
        let mut p = Self::base("CUDA (RTX 5090)", "cuda", Backend::Cuda,
                               Platform::Linux, false, 7.4, 7.4, 50_000.0);
        p.jitter_pct = 0.6;
        p
    }

    /// A zero-overhead profile for isolating substrate-real costs in tests
    /// and criterion benches.
    pub fn zero_overhead() -> Self {
        ImplementationProfile {
            name: "zero-overhead",
            implementation: "none",
            backend: Backend::Vulkan,
            platform: Platform::Linux,
            is_browser: false,
            phases: PhaseCosts([0; 8]),
            sync_ns: 0,
            seq_backpressure_ns: 0,
            submit_floor_ns: 0,
            map_fixed_ns: 0,
            map_per_byte_ns: 0.0,
            jitter_pct: 0.0,
            kernel_gflops: 2000.0,
            mem_gbps: 800.0,
        }
    }

    /// All Table 6 configurations, in the paper's row order.
    pub fn table6_catalog() -> Vec<ImplementationProfile> {
        vec![
            Self::dawn_vulkan_rtx5090(),
            Self::wgpu_vulkan_rtx5090(),
            Self::wgpu_vulkan_amd_igpu(),
            Self::wgpu_metal_m2(),
            Self::chrome_vulkan_rtx5090(),
            Self::chrome_d3d12_rtx2000(),
            Self::chrome_d3d12_intel(),
            Self::safari_metal_m2(),
            Self::firefox_metal_m2(),
            Self::firefox_d3d12_rtx2000(),
            Self::firefox_d3d12_intel(),
        ]
    }

    /// Sequential per-dispatch cost (what Table 6's right column measures).
    pub fn sequential_dispatch_ns(&self) -> u64 {
        (self.phases.total() + self.seq_backpressure_ns).max(self.submit_floor_ns)
    }

    /// Single-op per-dispatch cost (dispatch + per-op sync conflation).
    pub fn single_op_dispatch_ns(&self) -> u64 {
        self.phases.total().max(self.submit_floor_ns) + self.sync_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_split_preserves_total_and_submit_dominates() {
        let pc = PhaseCosts::from_total(32_500);
        assert_eq!(pc.total(), 32_500);
        // submit ~40% (Table 20's key observation)
        let frac = pc.0[7] as f64 / pc.total() as f64;
        assert!((0.35..=0.45).contains(&frac), "submit fraction {frac}");
    }

    #[test]
    fn calibration_matches_table6() {
        // sequential column
        let cases: &[(ImplementationProfile, f64, f64)] = &[
            (ImplementationProfile::dawn_vulkan_rtx5090(), 23.8, 496.8),
            (ImplementationProfile::wgpu_vulkan_rtx5090(), 35.8, 35.8),
            (ImplementationProfile::wgpu_vulkan_amd_igpu(), 24.5, 24.8),
            (ImplementationProfile::wgpu_metal_m2(), 71.1, 48.3),
            (ImplementationProfile::chrome_vulkan_rtx5090(), 32.8, 2071.2),
            (ImplementationProfile::safari_metal_m2(), 31.7, 248.0),
        ];
        for (p, seq_us, single_us) in cases {
            let seq = p.sequential_dispatch_ns() as f64 / 1e3;
            let single = p.single_op_dispatch_ns() as f64 / 1e3;
            assert!((seq - seq_us).abs() < 0.05, "{}: seq {seq} != {seq_us}", p.name);
            assert!(
                (single - single_us).abs() < 0.05,
                "{}: single {single} != {single_us}",
                p.name
            );
        }
    }

    #[test]
    fn firefox_floor_dominates() {
        let p = ImplementationProfile::firefox_metal_m2();
        let seq = p.sequential_dispatch_ns() as f64 / 1e3;
        assert!((seq - 1038.7).abs() < 0.1);
        let single = p.single_op_dispatch_ns() as f64 / 1e3;
        assert!((single - 103_490.0).abs() < 1.0);
    }

    #[test]
    fn single_op_overestimates_sequential_by_20x_on_dawn() {
        let p = ImplementationProfile::dawn_vulkan_rtx5090();
        let ratio = p.single_op_dispatch_ns() as f64 / p.sequential_dispatch_ns() as f64;
        assert!((15.0..=25.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn catalog_has_eleven_rows() {
        assert_eq!(ImplementationProfile::table6_catalog().len(), 11);
    }

    #[test]
    fn metal_has_expensive_map() {
        assert!(ImplementationProfile::wgpu_metal_m2().map_fixed_ns
                > ImplementationProfile::wgpu_vulkan_rtx5090().map_fixed_ns * 10);
    }
}

//! High-level dispatch helpers over the raw device API.
//!
//! [`run_kernel_dispatch`] performs the full per-operation call sequence the
//! paper instruments — one encoder, one pass, one dispatch, one submit —
//! which is exactly what torch-webgpu's eager executor does per FX node.
//! [`DispatchBatcher`] implements the command-batching experiment (16
//! dispatches per submit, Table 16's null result).

use super::bindgroup::{BindGroupDesc, BindGroupEntry, BindGroupLayoutDesc, BindGroupLayoutId, BindingType};
use super::buffer::BufferId;
use super::device::{Device, KernelRunner};
use super::pipeline::ComputePipelineId;
use crate::Result;

/// Create (and cache externally if desired) the layout matching a kernel
/// with `n_in` inputs and `n_out` outputs: inputs read-only, outputs RW.
pub fn kernel_layout(device: &mut Device, label: &str, n_in: usize, n_out: usize)
    -> Result<BindGroupLayoutId>
{
    let mut entries = vec![BindingType::ReadOnlyStorage; n_in];
    entries.extend(vec![BindingType::Storage; n_out]);
    device.create_bind_group_layout(BindGroupLayoutDesc {
        label: label.to_string(),
        entries,
    })
}

/// Bind `inputs ++ outputs` densely over `layout` (full-buffer ranges).
pub fn bind_buffers(
    device: &mut Device,
    label: &str,
    layout: BindGroupLayoutId,
    inputs: &[BufferId],
    outputs: &[BufferId],
) -> Result<super::bindgroup::BindGroupId> {
    let mut entries = Vec::with_capacity(inputs.len() + outputs.len());
    for (i, &b) in inputs.iter().chain(outputs.iter()).enumerate() {
        let size = device.buffer_size(b)?;
        entries.push(BindGroupEntry { binding: i, buffer: b, offset: 0, size });
    }
    device.create_bind_group(BindGroupDesc {
        label: label.to_string(),
        layout,
        entries,
    })
}

/// The full single-operation dispatch sequence (8 phases, Table 20 order).
/// Returns after submit — asynchronous, like `queue.Submit()`; callers that
/// need results synchronously must `poll_wait`/`map_read`.
pub fn run_kernel_dispatch(
    device: &mut Device,
    pipeline: ComputePipelineId,
    layout: BindGroupLayoutId,
    inputs: &[BufferId],
    outputs: &[BufferId],
    workgroups: (u32, u32, u32),
    runner: &dyn KernelRunner,
) -> Result<()> {
    let group = bind_buffers(device, "dispatch", layout, inputs, outputs)?;
    let enc = device.create_command_encoder("dispatch");
    device.begin_compute_pass(enc)?;
    device.set_pipeline(enc, pipeline)?;
    device.set_bind_group(enc, group)?;
    device.dispatch_workgroups(enc, workgroups.0, workgroups.1, workgroups.2)?;
    device.end_compute_pass(enc)?;
    let cb = device.finish(enc)?;
    device.submit(&[cb], runner)?;
    Ok(())
}

/// Command batching: accumulate N dispatches into one encoder and submit
/// together. The paper found ~0% end-to-end effect because autoregressive
/// generation forces a sync per token, flushing the batch anyway (§5.1).
pub struct DispatchBatcher {
    pub batch_size: usize,
    pending: Vec<(ComputePipelineId, super::bindgroup::BindGroupId, (u32, u32, u32))>,
}

impl DispatchBatcher {
    pub fn new(batch_size: usize) -> Self {
        DispatchBatcher { batch_size: batch_size.max(1), pending: Vec::new() }
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Queue one dispatch; flushes automatically when the batch fills.
    pub fn dispatch(
        &mut self,
        device: &mut Device,
        pipeline: ComputePipelineId,
        layout: BindGroupLayoutId,
        inputs: &[BufferId],
        outputs: &[BufferId],
        workgroups: (u32, u32, u32),
        runner: &dyn KernelRunner,
    ) -> Result<()> {
        let group = bind_buffers(device, "batched", layout, inputs, outputs)?;
        self.pending.push((pipeline, group, workgroups));
        if self.pending.len() >= self.batch_size {
            self.flush(device, runner)?;
        }
        Ok(())
    }

    /// Encode all pending dispatches into one command buffer and submit.
    pub fn flush(&mut self, device: &mut Device, runner: &dyn KernelRunner) -> Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        let enc = device.create_command_encoder("batch");
        device.begin_compute_pass(enc)?;
        for (pipe, group, wg) in self.pending.drain(..) {
            device.set_pipeline(enc, pipe)?;
            device.set_bind_group(enc, group)?;
            device.dispatch_workgroups(enc, wg.0, wg.1, wg.2)?;
        }
        device.end_compute_pass(enc)?;
        let cb = device.finish(enc)?;
        device.submit(&[cb], runner)?;
        Ok(())
    }
}

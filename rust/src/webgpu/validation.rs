//! Validation rules — the per-operation checks whose cost WebGPU's
//! security model imposes (the paper's root cause, §2.1). Factored out so
//! tests can exercise each rule in isolation.

use std::collections::HashMap;

use super::bindgroup::{BindGroupDesc, BindGroupLayoutDesc, BindingType};
use super::buffer::{Buffer, BufferDesc, BufferId, BufferUsage};
use super::limits::Limits;
use crate::{Error, Result};

pub fn validate_buffer_desc(desc: &BufferDesc, limits: &Limits) -> Result<()> {
    if desc.size == 0 {
        return Err(Error::Validation(format!("buffer '{}' has size 0", desc.label)));
    }
    if desc.size > limits.max_buffer_size {
        return Err(Error::LimitExceeded(format!(
            "buffer '{}' size {} > max {}",
            desc.label, desc.size, limits.max_buffer_size
        )));
    }
    if desc.usage.is_empty() {
        return Err(Error::Validation(format!("buffer '{}' has empty usage", desc.label)));
    }
    Ok(())
}

pub(crate) fn validate_write(buf: &Buffer, offset: usize, len: usize) -> Result<()> {
    if buf.destroyed {
        return Err(Error::Validation("write to destroyed buffer".into()));
    }
    if !buf.desc.usage.contains(BufferUsage::COPY_DST) {
        return Err(Error::Validation(format!(
            "write_buffer requires COPY_DST on '{}'",
            buf.desc.label
        )));
    }
    if offset + len > buf.desc.size {
        return Err(Error::Validation(format!(
            "write [{}..{}] out of bounds for '{}' (size {})",
            offset,
            offset + len,
            buf.desc.label,
            buf.desc.size
        )));
    }
    Ok(())
}

pub(crate) fn validate_bind_group(
    desc: &BindGroupDesc,
    layout: &BindGroupLayoutDesc,
    buffers: &HashMap<BufferId, Buffer>,
    limits: &Limits,
) -> Result<()> {
    if desc.entries.len() != layout.entries.len() {
        return Err(Error::Validation(format!(
            "bind group '{}' has {} entries, layout expects {}",
            desc.label,
            desc.entries.len(),
            layout.entries.len()
        )));
    }
    for (i, entry) in desc.entries.iter().enumerate() {
        if entry.binding != i {
            return Err(Error::Validation(format!(
                "bind group '{}': entries must be dense, entry {i} has binding {}",
                desc.label, entry.binding
            )));
        }
        let buf = buffers.get(&entry.buffer).ok_or_else(|| {
            Error::InvalidResource(format!("bind group '{}': buffer {:?}", desc.label, entry.buffer))
        })?;
        if buf.destroyed {
            return Err(Error::Validation(format!(
                "bind group '{}': buffer {:?} is destroyed",
                desc.label, entry.buffer
            )));
        }
        let required = match layout.entries[i] {
            BindingType::Storage | BindingType::ReadOnlyStorage => BufferUsage::STORAGE,
            BindingType::Uniform => BufferUsage::UNIFORM,
        };
        if !buf.desc.usage.contains(required) {
            return Err(Error::Validation(format!(
                "bind group '{}': binding {i} requires usage {:?}",
                desc.label, required
            )));
        }
        if entry.offset + entry.size > buf.desc.size {
            return Err(Error::Validation(format!(
                "bind group '{}': binding {i} range [{}..{}] exceeds buffer size {}",
                desc.label,
                entry.offset,
                entry.offset + entry.size,
                buf.desc.size
            )));
        }
        if entry.size > limits.max_storage_buffer_binding_size {
            return Err(Error::LimitExceeded(format!(
                "bind group '{}': binding {i} size {} > max binding size {}",
                desc.label, entry.size, limits.max_storage_buffer_binding_size
            )));
        }
    }
    Ok(())
}

pub fn validate_pipeline_interface(
    module: &super::pipeline::ShaderModuleDesc,
    layout: &BindGroupLayoutDesc,
) -> Result<()> {
    let expected = module.inputs.len() + module.outputs.len();
    if layout.entries.len() != expected {
        return Err(Error::Validation(format!(
            "pipeline '{}': layout has {} bindings, kernel needs {} ({} in + {} out)",
            module.label,
            layout.entries.len(),
            expected,
            module.inputs.len(),
            module.outputs.len()
        )));
    }
    // Inputs must be read-only storage; outputs read-write storage.
    for i in 0..module.inputs.len() {
        if layout.entries[i] == BindingType::Storage {
            return Err(Error::Validation(format!(
                "pipeline '{}': input binding {i} must not be writable",
                module.label
            )));
        }
    }
    for (j, entry) in layout.entries[module.inputs.len()..].iter().enumerate() {
        if *entry != BindingType::Storage {
            return Err(Error::Validation(format!(
                "pipeline '{}': output binding {} must be writable storage",
                module.label,
                module.inputs.len() + j
            )));
        }
    }
    Ok(())
}

//! Batched-decode integration tests: bit-identical equivalence between
//! batched serving rounds and interleaved planned decode across session
//! counts x fusion configs x ragged rounds, cross-slot cache isolation at
//! the byte level (mirroring `residency.rs`), partial-round masking
//! without recompiles, and the dispatches-per-round acceptance gate.

use wdb::engine::{EngineConfig, ExecMode, DEFAULT_BATCH_WIDTH};
use wdb::fx::builder::FusionConfig;
use wdb::runtime::Registry;
use wdb::serve::{ServeConfig, ServeReport, ServingEngine};

const SEED: u64 = 0xBA7C4;

fn registry() -> Registry {
    Registry::builtin().expect("builtin registry")
}

fn cfg(fusion: FusionConfig, batch_width: usize) -> EngineConfig {
    EngineConfig {
        fusion,
        exec: ExecMode::Planned,
        batch_width,
        // This suite pins BATCHED-DECODE behavior (one token per session
        // per round); chunked prompt ingestion has its own equivalence
        // suite in `tests/prefill.rs`.
        prefill_chunk: 0,
        // And it pins the PR 5 contiguous cache-set contract (per-session
        // DeviceKvCache buffers, slot_idx gather); the paged block-table
        // layout has its own suite in `tests/paged.rs` and takes the full
        // 50-seed differential sweep in `tests/schedules.rs`.
        paged: false,
        ..EngineConfig::tiny_fused()
    }
}

/// Run `prompts[i]` for `n_news[i]` tokens each on one engine; return each
/// session's token stream keyed by submission order.
fn run_sessions(
    reg: &Registry,
    config: EngineConfig,
    max_concurrent: usize,
    prompts: &[Vec<usize>],
    n_news: &[usize],
) -> Vec<Vec<usize>> {
    let mut se = ServingEngine::new(reg, ServeConfig { engine: config, max_concurrent })
        .expect("serving engine");
    se.reseed(SEED);
    let mut ids = Vec::new();
    for (p, &n) in prompts.iter().zip(n_news) {
        ids.push(se.submit(p, n).expect("submit"));
    }
    se.run_to_completion().expect("serve");
    let done = se.drain_finished();
    ids.iter()
        .map(|id| {
            done.iter()
                .find(|s| s.id == *id)
                .expect("session finished")
                .tokens
                .clone()
        })
        .collect()
}

/// Acceptance: batched decode is bit-identical to interleaved planned
/// decode for sessions {2, 3, 4} x {fused, unfused}, with RAGGED rounds —
/// every session requests a different token count, so sessions retire
/// mid-run and later rounds run partially masked (and eventually fall back
/// to the single-session path at 1 active).
#[test]
fn batched_matches_interleaved_across_sessions_fusion_ragged() {
    let reg = registry();
    for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
        for sessions in [2usize, 3, 4] {
            let prompts: Vec<Vec<usize>> = (0..sessions)
                .map(|i| vec![65 + i * 7, 90 + i, 120 + i * 3][..1 + i % 3].to_vec())
                .collect();
            let n_news: Vec<usize> = (0..sessions).map(|i| 3 + 2 * i).collect();
            let interleaved =
                run_sessions(&reg, cfg(fusion, 0), sessions, &prompts, &n_news);
            let batched = run_sessions(
                &reg,
                cfg(fusion, DEFAULT_BATCH_WIDTH),
                sessions,
                &prompts,
                &n_news,
            );
            assert_eq!(
                interleaved, batched,
                "{fusion:?} N={sessions}: batched diverged from interleaved"
            );
            // Ragged by construction: distinct lengths retire at
            // different rounds.
            assert!(n_news.windows(2).all(|w| w[0] != w[1]));
        }
    }
}

/// Partial rounds mask empty slots — no recompile, no new pipelines, and
/// a 3-active round on a width-4 plan still decodes correctly.
#[test]
fn partial_rounds_mask_slots_without_recompile() {
    let reg = registry();
    let prompts: Vec<Vec<usize>> = vec![vec![65, 66], vec![90], vec![120, 121, 122]];
    let n_news = [4usize, 4, 4];
    let expect = run_sessions(&reg, cfg(FusionConfig::fused(), 0), 3, &prompts, &n_news);

    let mut se = ServingEngine::new(
        &reg,
        // Width 4 with max_concurrent 4 but only 3 submissions: every
        // chunk leaves slot 3 masked against the padding set.
        ServeConfig { engine: cfg(FusionConfig::fused(), 4), max_concurrent: 4 },
    )
    .unwrap();
    se.reseed(SEED);
    assert_eq!(se.batch_width, 4);
    for (p, &n) in prompts.iter().zip(&n_news) {
        se.submit(p, n).unwrap();
    }
    // Pipelines exist after construction; rounds must not create more
    // (masking handles the ragged width, never a recompile).
    let pipes0 = se.executor.device.stats.pipelines_created;
    se.run_to_completion().unwrap();
    assert_eq!(
        se.executor.device.stats.pipelines_created, pipes0,
        "partial rounds must not recompile"
    );
    let runner = se.executor.batched_runner().expect("batched plan enabled");
    assert!(runner.rounds > 0, "batched rounds must have run");
    // Ragged retirement reshuffles slots, so more than one table may
    // register — but the count stays bounded by the packings seen.
    assert!((1..=3).contains(&runner.registered_tables()));
    let got: Vec<Vec<usize>> = se.drain_finished().into_iter().map(|s| s.tokens).collect();
    assert_eq!(got, expect);
}

/// Cross-slot cache isolation, byte level (mirrors
/// `residency.rs::session_cache_updates_never_touch_other_sessions_buffers`):
/// a detached session's device cache buffers are bit-identical before and
/// after OTHER sessions' batched rounds, and the detached session still
/// decodes the solo stream afterwards.
#[test]
fn batched_rounds_never_touch_other_sessions_cache_bytes() {
    let reg = registry();
    let solo_prompt = vec![72usize, 101, 108];
    let tokens = 5;

    // Solo truth on a batching-enabled engine (single-session path).
    let mut solo_se = ServingEngine::new(
        &reg,
        ServeConfig { engine: cfg(FusionConfig::fused(), 4), max_concurrent: 4 },
    )
    .unwrap();
    solo_se.reseed(SEED);
    let mut truth = solo_se.create_session(solo_prompt.clone(), tokens, 99);
    while !truth.finished() {
        let (t, p) = truth.take_input().unwrap();
        let h = solo_se.encode_session(&mut truth, t, p).unwrap();
        solo_se.finish_session(&mut truth, h).unwrap();
    }

    let mut se = ServingEngine::new(
        &reg,
        ServeConfig { engine: cfg(FusionConfig::fused(), 4), max_concurrent: 4 },
    )
    .unwrap();
    se.reseed(SEED);
    // Detached session C steps twice through the public (single-session)
    // API and then sits out while scheduled sessions run batched rounds.
    let mut c = se.create_session(solo_prompt.clone(), tokens, 7);
    for _ in 0..2 {
        let (t, p) = c.take_input().unwrap();
        let h = se.encode_session(&mut c, t, p).unwrap();
        se.finish_session(&mut c, h).unwrap();
    }
    let c_bufs = c.kv.as_device().expect("C promoted to device").buffers.clone();
    let snap: Vec<Vec<u8>> = c_bufs
        .iter()
        .map(|&b| se.executor.device.peek_buffer(b).unwrap().to_vec())
        .collect();

    // Two scheduled sessions decode through batched rounds.
    se.submit(&[65, 66], 4).unwrap();
    se.submit(&[90, 91], 4).unwrap();
    se.run_to_completion().unwrap();
    assert_eq!(se.drain_finished().len(), 2);

    for (i, &b) in c_bufs.iter().enumerate() {
        assert_eq!(
            se.executor.device.peek_buffer(b).unwrap(),
            snap[i].as_slice(),
            "batched cache scatter wrote into detached session's buffer {i}"
        );
    }
    // And C finishes with the solo stream.
    while !c.finished() {
        let (t, p) = c.take_input().unwrap();
        let h = se.encode_session(&mut c, t, p).unwrap();
        se.finish_session(&mut c, h).unwrap();
    }
    assert_eq!(c.tokens, truth.tokens, "detached session corrupted by batched rounds");
}

/// The KV state a session accumulates through batched rounds is
/// byte-identical to the state the same request accumulates solo: spill
/// both and compare tensors (slot scatter hits exactly the session's own
/// buffers at exactly its positions).
#[test]
fn batched_kv_state_spills_bit_identical_to_solo() {
    let reg = registry();
    let prompt_a = vec![65usize, 66, 67];
    let prompt_b = vec![90usize, 91];
    let rounds = 3usize;

    // Batched engine: two scheduled sessions, stepped `rounds` times.
    let mut se = ServingEngine::new(
        &reg,
        ServeConfig { engine: cfg(FusionConfig::fused(), 2), max_concurrent: 2 },
    )
    .unwrap();
    se.reseed(SEED);
    se.submit(&prompt_a, 8).unwrap();
    se.submit(&prompt_b, 8).unwrap();
    for _ in 0..rounds {
        assert_eq!(se.step_round().unwrap(), 2);
    }
    let mut a = se.active.remove(0);
    assert_eq!(a.pos, rounds);
    se.evict_session_cache(&mut a).unwrap();
    let spilled_a = a.kv.as_host().expect("spilled").clone();

    // Solo twin of session A, same number of steps.
    let mut solo = ServingEngine::new(
        &reg,
        ServeConfig { engine: cfg(FusionConfig::fused(), 0), max_concurrent: 1 },
    )
    .unwrap();
    solo.reseed(SEED);
    let mut s = solo.create_session(prompt_a, 8, 1);
    for _ in 0..rounds {
        let (t, p) = s.take_input().unwrap();
        let h = solo.encode_session(&mut s, t, p).unwrap();
        solo.finish_session(&mut s, h).unwrap();
    }
    solo.evict_session_cache(&mut s).unwrap();
    let spilled_solo = s.kv.as_host().expect("spilled").clone();

    assert_eq!(spilled_a.len(), spilled_solo.len());
    for (l, ((ka, va), (ks, vs))) in spilled_a.iter().zip(&spilled_solo).enumerate() {
        assert_eq!(
            ka.data.as_bytes(),
            ks.data.as_bytes(),
            "layer {l}: batched K cache bytes diverged from solo"
        );
        assert_eq!(
            va.data.as_bytes(),
            vs.data.as_bytes(),
            "layer {l}: batched V cache bytes diverged from solo"
        );
    }
}

/// Acceptance gate shape: at N=4, a batched round encodes at most HALF the
/// interleaved dispatches (it actually encodes ~1/4: one chunk of one
/// dispatch per layer op). Also pins the report's self-description.
#[test]
fn batched_round_dispatches_at_most_half_of_interleaved_at_n4() {
    let reg = registry();
    let prompt = vec![65usize, 66];
    let tokens = 5;
    let run = |bw: usize| -> ServeReport {
        let mut se = ServingEngine::new(
            &reg,
            ServeConfig { engine: cfg(FusionConfig::fused(), bw), max_concurrent: 4 },
        )
        .unwrap();
        se.reseed(SEED);
        for _ in 0..4 {
            se.submit(&prompt, tokens).unwrap();
        }
        se.run_to_completion().unwrap()
    };
    let interleaved = run(0);
    let batched = run(4);
    assert_eq!(interleaved.total_tokens, batched.total_tokens);
    assert!(interleaved.rounds > 0 && batched.rounds > 0);
    assert!(
        batched.dispatches_per_round() * 2.0 <= interleaved.dispatches_per_round(),
        "gate: batched {:.1} disp/round !<= interleaved {:.1} / 2",
        batched.dispatches_per_round(),
        interleaved.dispatches_per_round()
    );
    // The batched run issues strictly fewer dispatches overall.
    assert!(batched.dispatches < interleaved.dispatches);
    // Self-describing report (the serve header satellite).
    assert_eq!(batched.batch_width, 4);
    assert_eq!(batched.mode_label(), "planned+batched(w=4)");
    assert_eq!(interleaved.batch_width, 0);
    assert_eq!(interleaved.mode_label(), "planned");
}

/// Batching never engages for eager mode or single-session engines, and a
/// width above the built-in kernel coverage fails loudly at construction.
#[test]
fn batching_gates_on_mode_width_and_concurrency() {
    let reg = registry();
    let eager = ServingEngine::new(
        &reg,
        ServeConfig {
            engine: EngineConfig { batch_width: 4, ..EngineConfig::tiny_fused() },
            max_concurrent: 4,
        },
    )
    .unwrap();
    assert!(eager.batched_graph.is_none(), "eager engines must not batch");

    let single = ServingEngine::new(
        &reg,
        ServeConfig { engine: cfg(FusionConfig::fused(), 4), max_concurrent: 1 },
    )
    .unwrap();
    assert!(single.batched_graph.is_none(), "N=1 engines must not batch");
    assert_eq!(single.batch_width, 0);

    let disabled = ServingEngine::new(
        &reg,
        ServeConfig { engine: cfg(FusionConfig::fused(), 0), max_concurrent: 4 },
    )
    .unwrap();
    assert!(disabled.batched_graph.is_none(), "--no-batch must disable");

    let too_wide = ServingEngine::new(
        &reg,
        ServeConfig { engine: cfg(FusionConfig::fused(), 64), max_concurrent: 64 },
    );
    assert!(too_wide.is_err(), "width beyond builtin kernel coverage must error");
    // The REQUESTED width is validated before the max_concurrent clamp:
    // the same --batch-width is rejected regardless of --concurrent.
    let too_wide_low_mc = ServingEngine::new(
        &reg,
        ServeConfig { engine: cfg(FusionConfig::fused(), 9), max_concurrent: 2 },
    );
    assert!(too_wide_low_mc.is_err(), "over-wide request must not pass via the clamp");

    // Width caps at max_concurrent.
    let capped = ServingEngine::new(
        &reg,
        ServeConfig { engine: cfg(FusionConfig::fused(), 8), max_concurrent: 3 },
    )
    .unwrap();
    assert_eq!(capped.batch_width, 3);
}

/// More sessions than the batch width run in chunks per round and still
/// match the interleaved streams (N=6 over width 4 -> chunks of 4 + 2).
#[test]
fn chunked_rounds_above_width_match_interleaved() {
    let reg = registry();
    let sessions = 6usize;
    let prompts: Vec<Vec<usize>> = (0..sessions).map(|i| vec![60 + i * 5]).collect();
    let n_news: Vec<usize> = (0..sessions).map(|i| 3 + i % 2).collect();
    let interleaved =
        run_sessions(&reg, cfg(FusionConfig::fused(), 0), sessions, &prompts, &n_news);
    let batched =
        run_sessions(&reg, cfg(FusionConfig::fused(), 4), sessions, &prompts, &n_news);
    assert_eq!(interleaved, batched, "chunked batched rounds diverged");
}

/// Late admission joins batched rounds mid-run (continuous scheduling) and
/// every stream still matches the interleaved engine.
#[test]
fn mid_run_admission_joins_batched_rounds() {
    let reg = registry();
    let run = |bw: usize| -> Vec<Vec<usize>> {
        let mut se = ServingEngine::new(
            &reg,
            ServeConfig { engine: cfg(FusionConfig::fused(), bw), max_concurrent: 2 },
        )
        .unwrap();
        se.reseed(SEED);
        let ida = se.submit(&[65, 66], 6).unwrap();
        let idb = se.submit(&[90], 3).unwrap();
        // B retires early; C is admitted from the backlog mid-run.
        let idc = se.submit(&[120, 121], 4).unwrap();
        se.run_to_completion().unwrap();
        let done = se.drain_finished();
        [ida, idb, idc]
            .iter()
            .map(|id| done.iter().find(|s| s.id == *id).unwrap().tokens.clone())
            .collect()
    };
    assert_eq!(run(0), run(2), "admission churn diverged under batching");
}

/// Sticky slot assignment: sessions pin their decode slot at admission
/// and free it only on retire, so ragged retirement never reshuffles the
/// surviving sessions' rows — and a replacement admission (handed the
/// retiree's recycled buffer set by the pool's LIFO free lists) lands in
/// the retiree's slot, keeping the cache-set-table bind-group key
/// IDENTICAL across churn: exactly ONE table registers over the whole
/// churny run (pre-sticky, the admission-order repacking registered a new
/// table whenever retirement reshuffled the survivors).
#[test]
fn sticky_slots_keep_cache_set_table_stable_across_churn() {
    let reg = registry();
    let mut se = ServingEngine::new(
        &reg,
        ServeConfig { engine: cfg(FusionConfig::fused(), 4), max_concurrent: 3 },
    )
    .unwrap();
    se.reseed(SEED);
    let ida = se.submit(&[65], 8).unwrap(); // slot 0, rounds 1..=8
    let idb = se.submit(&[70], 3).unwrap(); // slot 1, retires after round 3
    let idc = se.submit(&[75], 8).unwrap(); // slot 2, rounds 1..=8
    let idd = se.submit(&[80], 6).unwrap(); // takes B's slot 1 + buffers
    se.run_to_completion().unwrap();
    let runner = se.executor.batched_runner().expect("batched plan enabled");
    assert_eq!(
        runner.registered_tables(),
        1,
        "sticky slots + recycled sets must keep ONE table key across churn"
    );
    let done = se.drain_finished();
    assert_eq!(done.len(), 4);
    let slot_of = |id: u64| done.iter().find(|s| s.id == id).unwrap().slot;
    assert_eq!(slot_of(ida), Some(0));
    assert_eq!(slot_of(idb), Some(1));
    assert_eq!(slot_of(idc), Some(2));
    assert_eq!(slot_of(idd), Some(1), "replacement admission reuses the freed slot");
}

/// SessionState is untouched by batching from the caller's view: steps
/// count one per round, positions advance once per round, and per-session
/// dispatch attribution sums to the engine total.
#[test]
fn batched_attribution_tiles_engine_totals() {
    let reg = registry();
    let mut se = ServingEngine::new(
        &reg,
        ServeConfig { engine: cfg(FusionConfig::fused(), 4), max_concurrent: 4 },
    )
    .unwrap();
    se.reseed(SEED);
    for i in 0..4 {
        se.submit(&[65 + i], 4).unwrap();
    }
    let report = se.run_to_completion().unwrap();
    let total_attr: u64 = se.drain_finished().iter().map(|s| s.metrics.dispatches).sum();
    assert_eq!(
        total_attr, se.executor.dispatch_count,
        "per-session dispatch shares must tile the engine total"
    );
    assert_eq!(report.dispatches, total_attr);
    assert!(report.steps == 4 * 4, "one step per session per round");
    // Identical-length sessions keep one stable slot packing: exactly ONE
    // cache-set table is ever registered (bind groups stay cache-hot).
    let runner = se.executor.batched_runner().expect("batched");
    assert_eq!(runner.registered_tables(), 1, "stable rounds must reuse one table");
    assert!(runner.rounds >= 4);
}

//! Fault-injection matrix for the serving engine: every injected failure
//! kind (dispatch failure, allocation failure, map-read timeout, device
//! loss), in every round phase (prefill, decode), under every scheduling
//! mode (unified, split, interleaved), must either be absorbed by
//! per-session quarantine + snapshot-replay recovery with BYTE-IDENTICAL
//! token streams — or, for device loss, surface as the typed fatal error.
//!
//! Trigger placement is derived from a clean twin's dispatch counts
//! rather than hard-coded opportunity indices, so the matrix stays valid
//! when kernel fusion or scheduling changes the dispatch bill: the
//! prefill trigger lands halfway through the prompt-phase dispatches,
//! the decode trigger halfway through the decode-phase remainder.

use wdb::engine::{EngineConfig, ExecMode};
use wdb::fx::builder::FusionConfig;
use wdb::runtime::Registry;
use wdb::serve::{ServeConfig, ServeReport, ServingEngine, SessionState};
use wdb::webgpu::{FaultKind, FaultPlan, FaultTrigger};

/// Virtual-cost jitter seed — identical for clean and faulty twins so the
/// only difference between runs is the fault plan.
const RESEED: u64 = 0xFA57;
const PROMPT_LEN: usize = 5;
const TOKENS: usize = 8;

fn registry() -> Registry {
    Registry::builtin().expect("builtin registry")
}

fn unified_cfg() -> EngineConfig {
    EngineConfig {
        fusion: FusionConfig::fused(),
        exec: ExecMode::Planned,
        ..EngineConfig::tiny_fused()
    }
}

fn split_cfg() -> EngineConfig {
    EngineConfig { unified: false, ..unified_cfg() }
}

fn interleaved_cfg() -> EngineConfig {
    EngineConfig { batch_width: 0, prefill_chunk: 0, ..unified_cfg() }
}

fn modes() -> [(&'static str, EngineConfig); 3] {
    [
        ("unified", unified_cfg()),
        ("split", split_cfg()),
        ("interleaved", interleaved_cfg()),
    ]
}

/// Drive `n` oversubscription-free sessions (distinct prompts) through one
/// engine, optionally arming a hand-built fault plan after construction
/// (mirroring the `fault_seed` arming point: plan build never faults).
/// Returns (per-request token streams in submission order, report,
/// finished sessions).
fn run_sessions(
    reg: &Registry,
    cfg: EngineConfig,
    plan: Option<FaultPlan>,
    n: usize,
) -> (Vec<Vec<usize>>, ServeReport, Vec<SessionState>) {
    let mut se = ServingEngine::new(reg, ServeConfig { engine: cfg, max_concurrent: n })
        .expect("serving engine");
    if let Some(p) = plan {
        se.install_fault_plan(p);
    }
    se.reseed(RESEED);
    let ids: Vec<u64> = (0..n)
        .map(|i| {
            let prompt: Vec<usize> =
                (0..PROMPT_LEN).map(|t| 7 + (t * 13 + i * 31) % 500).collect();
            se.submit(&prompt, TOKENS).expect("submit")
        })
        .collect();
    let report = se.run_to_completion().expect("run_to_completion");
    let done = se.drain_finished();
    let toks = ids
        .iter()
        .map(|id| done.iter().find(|s| s.id == *id).expect("finished").tokens.clone())
        .collect();
    (toks, report, done)
}

/// A faulty run whose plan is transient-only must complete every session
/// with the clean twin's exact token streams, inject at least one fault,
/// and fail nobody.
fn assert_recovers(
    label: &str,
    reg: &Registry,
    cfg: EngineConfig,
    plan: FaultPlan,
    n: usize,
    clean_toks: &[Vec<usize>],
) -> ServeReport {
    let (f_toks, f_rep, done) = run_sessions(reg, cfg, Some(plan), n);
    assert_eq!(clean_toks, &f_toks[..], "{label}: token streams diverged under faults");
    assert!(f_rep.faults_injected >= 1, "{label}: the trigger never fired");
    assert!(f_rep.retries >= 1, "{label}: a fault fired but nothing retried");
    assert_eq!(f_rep.failed_sessions, 0, "{label}: transient fault failed a session");
    assert!(done.iter().all(|s| !s.failed), "{label}: a drained session is marked failed");
    f_rep
}

/// Dispatch-phase trigger placement off the clean twin's dispatch split.
fn prefill_at(clean: &ServeReport) -> u64 {
    (clean.prefill_dispatches / 2).max(1)
}

fn decode_at(clean: &ServeReport) -> u64 {
    clean.prefill_dispatches + (clean.dispatches - clean.prefill_dispatches) / 2
}

#[test]
fn dispatch_fault_in_prefill_recovers_in_every_mode() {
    let reg = registry();
    for (label, cfg) in modes() {
        let (c_toks, c_rep, _) = run_sessions(&reg, cfg.clone(), None, 2);
        assert!(c_rep.prefill_dispatches >= 2, "{label}: no prompt phase to fault");
        let plan = FaultPlan::new(vec![FaultTrigger {
            kind: FaultKind::DispatchFail,
            at: prefill_at(&c_rep),
        }]);
        let f_rep = assert_recovers(label, &reg, cfg, plan, 2, &c_toks);
        // Quarantine rolled the hit session(s) back and replayed: the
        // recovery is attributed, not silent.
        assert!(
            f_rep.recovered_sessions >= 1,
            "{label}: no session recorded as recovered"
        );
    }
}

#[test]
fn dispatch_fault_in_decode_recovers_in_every_mode() {
    let reg = registry();
    for (label, cfg) in modes() {
        let (c_toks, c_rep, _) = run_sessions(&reg, cfg.clone(), None, 2);
        assert!(
            c_rep.dispatches > c_rep.prefill_dispatches,
            "{label}: no decode phase to fault"
        );
        let plan = FaultPlan::new(vec![FaultTrigger {
            kind: FaultKind::DispatchFail,
            at: decode_at(&c_rep),
        }]);
        let f_rep = assert_recovers(label, &reg, cfg, plan, 2, &c_toks);
        assert!(
            f_rep.recovered_sessions >= 1,
            "{label}: no session recorded as recovered"
        );
    }
}

#[test]
fn map_timeout_recovers_in_every_mode() {
    let reg = registry();
    for (label, cfg) in modes() {
        let (c_toks, _, _) = run_sessions(&reg, cfg.clone(), None, 2);
        // The second coalesced readback of the run times out; the bounded
        // map-retry loop re-issues it without touching any session state,
        // so no quarantine (and no recovered_sessions) is expected.
        let plan = FaultPlan::new(vec![FaultTrigger { kind: FaultKind::MapTimeout, at: 2 }]);
        assert_recovers(label, &reg, cfg, plan, 2, &c_toks);
    }
}

#[test]
fn alloc_fault_at_admission_recovers_in_every_mode() {
    let reg = registry();
    for (label, cfg) in modes() {
        let (c_toks, _, _) = run_sessions(&reg, cfg.clone(), None, 2);
        // The very first buffer creation after arming is the first
        // session's KV-cache allocation (plan-owned buffers predate the
        // injector); admission retries it inline.
        let plan = FaultPlan::new(vec![FaultTrigger { kind: FaultKind::AllocFail, at: 1 }]);
        assert_recovers(label, &reg, cfg, plan, 2, &c_toks);
    }
}

/// Fault isolation: in interleaved mode every replay belongs to exactly
/// one session, so a single decode-phase dispatch fault must quarantine
/// exactly one session — the others' rounds continue uninterrupted.
#[test]
fn single_fault_quarantines_only_the_implicated_session() {
    let reg = registry();
    let (c_toks, c_rep, _) = run_sessions(&reg, interleaved_cfg(), None, 3);
    let plan = FaultPlan::new(vec![FaultTrigger {
        kind: FaultKind::DispatchFail,
        at: decode_at(&c_rep),
    }]);
    let f_rep = assert_recovers("isolation", &reg, interleaved_cfg(), plan, 3, &c_toks);
    assert_eq!(
        f_rep.recovered_sessions, 1,
        "a solo-replay fault must implicate exactly one session"
    );
}

/// Several transient faults of different kinds in one run: all absorbed.
#[test]
fn mixed_fault_plan_recovers_on_the_unified_path() {
    let reg = registry();
    let (c_toks, c_rep, _) = run_sessions(&reg, unified_cfg(), None, 3);
    let plan = FaultPlan::new(vec![
        FaultTrigger { kind: FaultKind::AllocFail, at: 1 },
        FaultTrigger { kind: FaultKind::DispatchFail, at: prefill_at(&c_rep) },
        FaultTrigger { kind: FaultKind::DispatchFail, at: decode_at(&c_rep) },
        FaultTrigger { kind: FaultKind::MapTimeout, at: 3 },
    ]);
    let f_rep = assert_recovers("mixed", &reg, unified_cfg(), plan, 3, &c_toks);
    assert!(f_rep.faults_injected >= 3, "most of the mixed plan should land");
}

/// Seeded plans (the differential-suite arm and the CI bench gate) must
/// recover across a spread of seeds with streams identical to clean.
#[test]
fn seeded_plans_recover_with_identical_streams() {
    let reg = registry();
    let (c_toks, _, _) = run_sessions(&reg, unified_cfg(), None, 3);
    for seed in 0..6u64 {
        let cfg = EngineConfig { fault_seed: Some(seed), ..unified_cfg() };
        let (f_toks, f_rep, done) = run_sessions(&reg, cfg, None, 3);
        assert_eq!(c_toks, f_toks, "seed {seed}: streams diverged");
        assert_eq!(f_rep.failed_sessions, 0, "seed {seed}: a session failed");
        assert_eq!(f_rep.fault_seed, Some(seed), "seed {seed}: report lost its seed");
        assert!(done.iter().all(|s| !s.failed));
    }
}

/// Device loss is fatal and device-scoped: the run aborts with the typed
/// error instead of quarantining, in every scheduling mode.
#[test]
fn device_loss_is_fatal_in_every_mode() {
    let reg = registry();
    for (label, cfg) in modes() {
        let mut se = ServingEngine::new(
            &reg,
            ServeConfig { engine: cfg, max_concurrent: 2 },
        )
        .expect("serving engine");
        se.install_fault_plan(FaultPlan::new(vec![FaultTrigger {
            kind: FaultKind::DeviceLost,
            at: 10,
        }]));
        se.reseed(RESEED);
        for i in 0..2usize {
            let prompt: Vec<usize> =
                (0..PROMPT_LEN).map(|t| 7 + (t * 13 + i * 31) % 500).collect();
            se.submit(&prompt, TOKENS).expect("submit");
        }
        let err = se.run_to_completion().expect_err("device loss must abort the run");
        assert!(err.is_device_lost(), "{label}: wrong error class: {err}");
    }
}

/// A session facing persistent (non-one-shot) faults exhausts its retry
/// budget, is marked failed and swept — and the engine TERMINATES instead
/// of spinning, with the failure attributed in the report.
#[test]
fn persistent_faults_fail_sessions_but_terminate() {
    let reg = registry();
    // Every dispatch opportunity fails: no replay can ever complete.
    let triggers: Vec<FaultTrigger> = (1..=20_000u64)
        .map(|at| FaultTrigger { kind: FaultKind::DispatchFail, at })
        .collect();
    let mut se = ServingEngine::new(
        &reg,
        ServeConfig { engine: unified_cfg(), max_concurrent: 2 },
    )
    .expect("serving engine");
    se.install_fault_plan(FaultPlan::new(triggers));
    se.reseed(RESEED);
    for i in 0..2usize {
        let prompt: Vec<usize> =
            (0..PROMPT_LEN).map(|t| 7 + (t * 13 + i * 31) % 500).collect();
        se.submit(&prompt, TOKENS).expect("submit");
    }
    let report = se.run_to_completion().expect("persistent faults are still session-scoped");
    assert_eq!(report.failed_sessions, 2, "both sessions must exhaust the retry budget");
    assert_eq!(report.recovered_sessions, 0);
    let done = se.drain_finished();
    assert_eq!(done.len(), 2, "failed sessions are swept into finished");
    for s in &done {
        assert!(s.failed, "session {} should be marked failed", s.id);
        assert!(
            s.tokens.len() < TOKENS,
            "a session that never replayed cannot have finished generating"
        );
    }
}

/// The `+faults(seed=N)` mode label and fault counters surface in the
/// report so bench artifacts name the experiment that actually ran.
#[test]
fn report_carries_fault_observability() {
    let reg = registry();
    let cfg = EngineConfig { fault_seed: Some(9), ..unified_cfg() };
    let (_, rep, _) = run_sessions(&reg, cfg, None, 2);
    assert!(
        rep.mode_label().ends_with("+faults(seed=9)"),
        "mode label missing the faults tag: {}",
        rep.mode_label()
    );
    let clean = run_sessions(&reg, unified_cfg(), None, 2).1;
    assert_eq!(clean.fault_seed, None);
    assert!(!clean.mode_label().contains("+faults"));
    assert_eq!(clean.faults_injected, 0);
    assert_eq!(clean.retries, 0);
}

//! End-to-end integration: registry -> kernel runtime -> WebGPU substrate
//! -> engine, exercising the full three-layer stack: the tiny Qwen config
//! decoding real tokens through per-op dispatches.
//!
//! With `make artifacts` + `--features pjrt` these run the PJRT CPU
//! client; otherwise `Registry::open()` falls back to the built-in
//! manifest + host reference interpreter, so the suite is hermetic (the
//! seed's hard dependency on artifacts was the tier-1 red).

use std::collections::HashMap;

use wdb::engine::{run_protocol, Engine, EngineConfig};
use wdb::fx::builder::{build_decode_graph, expected_dispatches, FusionConfig, GraphDims};
use wdb::model::ByteTokenizer;
use wdb::runtime::Registry;
use wdb::tensor::Tensor;
use wdb::webgpu::ImplementationProfile;

fn registry() -> Registry {
    std::env::set_var("WDB_ARTIFACTS", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    Registry::open().expect("registry (artifacts or builtin fallback)")
}

#[test]
fn manifest_covers_tiny_graphs() {
    let reg = registry();
    let dims = GraphDims::qwen_tiny();
    for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
        let g = build_decode_graph(&dims, fusion);
        for name in g.kernel_names() {
            assert!(
                reg.kernels.contains_key(&name),
                "kernel '{name}' missing from manifest"
            );
        }
    }
}

#[test]
fn registry_executes_a_kernel() {
    let reg = registry();
    let x = Tensor::f32(vec![1, 64], (0..64).map(|i| i as f32 / 64.0).collect()).unwrap();
    let w = Tensor::f32(vec![64], vec![1.0; 64]).unwrap();
    let (outs, ns) = reg.execute("rmsnorm_64", &[x.clone(), w]).unwrap();
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].shape, vec![1, 64]);
    // RMSNorm output has unit RMS with unit weight.
    let v = outs[0].as_f32().unwrap();
    let rms: f32 = (v.iter().map(|x| x * x).sum::<f32>() / 64.0).sqrt();
    assert!((rms - 1.0).abs() < 1e-3, "rms {rms}");
    assert!(ns > 0);
}

#[test]
fn registry_rejects_bad_shapes() {
    let reg = registry();
    let x = Tensor::f32(vec![1, 32], vec![0.0; 32]).unwrap();
    let w = Tensor::f32(vec![64], vec![1.0; 64]).unwrap();
    assert!(reg.execute("rmsnorm_64", &[x, w]).is_err());
}

#[test]
fn engine_generates_deterministic_tokens() {
    let reg = registry();
    let mut engine = Engine::new(&reg, EngineConfig::tiny_fused()).unwrap();
    let tok = ByteTokenizer::new(512);
    let prompt = tok.paper_prompt();
    let a = engine.generate(&prompt, 8).unwrap();
    let b = engine.generate(&prompt, 8).unwrap();
    assert_eq!(a.tokens, b.tokens, "generation must be deterministic");
    assert_eq!(a.tokens.len(), 8);
    assert!(a.tokens.iter().all(|&t| t < 512));
    assert!(a.ttft_ns > 0 && a.total_ns >= a.ttft_ns);
}

#[test]
fn fused_and_unfused_generate_identical_tokens() {
    // The paper's fusion is numerics-preserving (Appendix N): the token
    // stream must not change, only the dispatch count and timing.
    let reg = registry();
    let prompt = ByteTokenizer::new(512).paper_prompt();
    let mut fused = Engine::new(&reg, EngineConfig::tiny_fused()).unwrap();
    let mut unfused = Engine::new(&reg, EngineConfig::tiny_unfused()).unwrap();
    let rf = fused.generate(&prompt, 6).unwrap();
    let ru = unfused.generate(&prompt, 6).unwrap();
    assert_eq!(rf.tokens, ru.tokens, "fusion changed the token stream");
    // Dispatch counts per step match the graph arithmetic.
    let dims = GraphDims::qwen_tiny();
    assert_eq!(
        rf.dispatches_per_step as usize,
        expected_dispatches(&dims, FusionConfig::fused())
    );
    assert_eq!(
        ru.dispatches_per_step as usize,
        expected_dispatches(&dims, FusionConfig::unfused())
    );
    // Unfused pays more virtual time per token.
    assert!(ru.ttft_ns > rf.ttft_ns, "unfused must be slower");
}

#[test]
fn fusion_improves_throughput_on_vulkan() {
    let reg = registry();
    let prompt = ByteTokenizer::new(512).paper_prompt();
    let mut fused = Engine::new(&reg, EngineConfig::tiny_fused()).unwrap();
    let mut unfused = Engine::new(&reg, EngineConfig::tiny_unfused()).unwrap();
    let rf = fused.generate(&prompt, 6).unwrap();
    let ru = unfused.generate(&prompt, 6).unwrap();
    let speedup = rf.tok_per_s / ru.tok_per_s;
    // Tiny config has ~2.6x fewer dispatches when fused; with per-op
    // overhead dominating, throughput must improve substantially.
    assert!(speedup > 1.5, "fusion speedup only {speedup:.2}x");
}

#[test]
fn device_argmax_matches_host_argmax() {
    let reg = registry();
    let prompt = ByteTokenizer::new(512).paper_prompt();
    let mut host = Engine::new(&reg, EngineConfig::tiny_fused()).unwrap();
    let mut dev = Engine::new(
        &reg,
        EngineConfig { device_argmax: true, ..EngineConfig::tiny_fused() },
    )
    .unwrap();
    let rh = host.generate(&prompt, 5).unwrap();
    let rd = dev.generate(&prompt, 5).unwrap();
    assert_eq!(rh.tokens, rd.tokens, "device argmax changed tokens");
}

#[test]
fn protocol_reports_stable_stats() {
    let reg = registry();
    let prompt = ByteTokenizer::new(512).paper_prompt();
    let mut engine = Engine::new(&reg, EngineConfig::tiny_fused()).unwrap();
    let r = run_protocol(&mut engine, &prompt, 5, 1, 5).unwrap();
    assert_eq!(r.runs, 5);
    assert!(r.tok_per_s.mean > 0.0);
    assert!(r.tok_per_s.cv < 0.10, "CV {:.3} too high", r.tok_per_s.cv);
    assert!(r.tok_per_s.ci95_lo <= r.tok_per_s.mean);
    assert!(r.tok_per_s.mean <= r.tok_per_s.ci95_hi);
}

#[test]
fn firefox_profile_is_rate_limited() {
    let reg = registry();
    let prompt = vec![84usize];
    let mk = |profile: ImplementationProfile| EngineConfig {
        profile,
        ..EngineConfig::tiny_fused()
    };
    let mut dawn = Engine::new(&reg, mk(ImplementationProfile::dawn_vulkan_rtx5090())).unwrap();
    let mut ff = Engine::new(&reg, mk(ImplementationProfile::firefox_metal_m2())).unwrap();
    let rd = dawn.generate(&prompt, 3).unwrap();
    let rf = ff.generate(&prompt, 3).unwrap();
    // ~1040 us floor vs ~24 us dispatch (+ framework): Firefox must be far
    // slower end-to-end.
    assert!(
        rf.total_ns > rd.total_ns * 8,
        "firefox {} vs dawn {}",
        rf.total_ns,
        rd.total_ns
    );
}

#[test]
fn executor_pools_buffers() {
    let reg = registry();
    let prompt = vec![10usize];
    let mut engine = Engine::new(&reg, EngineConfig::tiny_fused()).unwrap();
    let _ = engine.generate(&prompt, 2).unwrap();
    let created_after_two = engine.executor.device.stats.buffers_created;
    let _ = engine.generate(&prompt, 4).unwrap();
    let created_after_more = engine.executor.device.stats.buffers_created;
    // Pool reuse: more tokens must not create proportionally more buffers.
    let growth = created_after_more - created_after_two;
    assert!(
        growth < created_after_two / 2,
        "buffer churn: {created_after_two} then +{growth}"
    );
}

#[test]
fn graph_inputs_all_satisfiable() {
    // Every input the graph declares is provided by engine step() logic:
    // indirectly verified by generate() succeeding with a fresh engine for
    // each fusion preset.
    let reg = registry();
    for fusion in [
        FusionConfig::unfused(),
        FusionConfig::rmsnorm_only(),
        FusionConfig::rmsnorm_mlp(),
        FusionConfig::fused(),
    ] {
        let mut engine = Engine::new(
            &reg,
            EngineConfig { fusion, ..EngineConfig::tiny_fused() },
        )
        .unwrap();
        let r = engine.generate(&[65], 2).unwrap();
        assert_eq!(r.tokens.len(), 2, "fusion {fusion:?}");
    }
}

#[test]
fn cache_state_evolves_with_position() {
    let reg = registry();
    let mut engine = Engine::new(&reg, EngineConfig::tiny_fused()).unwrap();
    // Generating from two different prompts must diverge (cache matters).
    let a = engine.generate(&[65, 66], 4).unwrap();
    let b = engine.generate(&[90, 91], 4).unwrap();
    assert_ne!(a.tokens, b.tokens, "prompt had no effect — cache broken?");
}

#[test]
fn null_inputs_rejected() {
    let reg = registry();
    let mut engine = Engine::new(&reg, EngineConfig::tiny_fused()).unwrap();
    assert!(engine.generate(&[], 5).is_err());
    assert!(engine.generate(&[65], 0).is_err());
}

#[test]
fn graph_executor_rejects_missing_input() {
    let reg = registry();
    let dims = GraphDims::qwen_tiny();
    let g = build_decode_graph(&dims, FusionConfig::fused());
    let device = wdb::webgpu::Device::new(ImplementationProfile::zero_overhead());
    let mut ex = wdb::engine::GraphExecutor::new(device, &reg, 0);
    ex.prepare(&g).unwrap();
    let inputs: HashMap<String, Tensor> = HashMap::new();
    assert!(ex.run(&g, &inputs).is_err());
}

//! Paged KV residency integration tests — the PR 9 acceptance gates.
//!
//! The paged layout (fixed `kv_block`-token blocks from a shared pool +
//! per-slot block tables, with a per-block LRU pager) is the planned
//! serving default. This suite pins its contract against the PR 3
//! contiguous baseline:
//!
//!   - block-boundary prompt lengths ({b-1, b, b+1, 3b+5}) produce
//!     byte-identical token streams AND spilled-KV bytes vs `paged: false`;
//!   - a partially filled tail block evicts to host and re-hydrates
//!     bit-identically mid-generation;
//!   - speculative rewind across a block boundary never moves a byte;
//!   - the dispatch census is unchanged — the block table is bound as a
//!     uniform, so paged rounds encode exactly the contiguous counts;
//!   - >= 4x sessions resident at equal pool cap (the density headline);
//!   - 2x oversubscription defers and pages, never fails.

use wdb::engine::{EngineConfig, ExecMode, DEFAULT_KV_BLOCK};
use wdb::fx::builder::GraphDims;
use wdb::runtime::Registry;
use wdb::serve::{ServeConfig, ServeReport, ServingEngine, SessionState};

const SEED: u64 = 0x9A6ED;

fn registry() -> Registry {
    Registry::builtin().expect("builtin registry")
}

fn paged_cfg() -> EngineConfig {
    let cfg = EngineConfig { exec: ExecMode::Planned, ..EngineConfig::tiny_fused() };
    assert!(cfg.paged, "paged is the planned serving default");
    assert_eq!(cfg.kv_block, DEFAULT_KV_BLOCK);
    cfg
}

fn contiguous_cfg() -> EngineConfig {
    EngineConfig { paged: false, ..paged_cfg() }
}

/// Contiguous bytes of one session's full KV-cache set — the equal-cap
/// unit for density comparisons.
fn set_bytes() -> usize {
    let dims = GraphDims::qwen_tiny();
    2 * dims.layers * dims.max_seq * dims.kv_heads * dims.head_dim * 4
}

/// Run `reqs` (all submitted up front) to completion; probe the target
/// session's spilled-KV bytes the first round it holds >= `probe_tokens`
/// generated tokens (0 disables the probe). The probe evicts to host and
/// lets the next round re-hydrate — the spill/resume path is part of
/// every comparison. Returns (streams, probe KV bytes, report).
fn run(
    reg: &Registry,
    cfg: EngineConfig,
    max_concurrent: usize,
    reqs: &[(Vec<usize>, usize)],
    target: usize,
    probe_tokens: usize,
) -> (Vec<Vec<usize>>, Vec<Vec<u8>>, ServeReport) {
    let mut se = ServingEngine::new(reg, ServeConfig { engine: cfg, max_concurrent })
        .expect("serving engine");
    se.reseed(SEED);
    let ids: Vec<u64> = reqs
        .iter()
        .map(|(prompt, gen)| se.submit(prompt, *gen).expect("submit"))
        .collect();
    let mut kv: Vec<Vec<u8>> = Vec::new();
    if probe_tokens > 0 {
        let mut rounds = 0usize;
        while kv.is_empty() && (!se.active.is_empty() || !se.queue.is_empty()) {
            se.step_round().expect("step_round");
            if let Some(pos) = se
                .active
                .iter()
                .position(|s| s.id == ids[target] && s.tokens.len() >= probe_tokens)
            {
                let mut s = se.active.remove(pos);
                se.evict_session_cache(&mut s).expect("evict");
                assert!(!s.kv.is_device(), "evicted session is host-resident");
                for (k, v) in s.kv.as_host().expect("spilled") {
                    kv.push(k.data.as_bytes().to_vec());
                    kv.push(v.data.as_bytes().to_vec());
                }
                se.active.insert(pos, s);
            }
            rounds += 1;
            assert!(rounds < 10_000, "probe failed to fire");
        }
    }
    // Sessions that finished before the probe fired are excluded from the
    // report's aggregates (probing tests only read streams + KV bytes);
    // probe-free runs get the full-run report.
    let report = se.run_to_completion().expect("drain report");
    let done = se.drain_finished();
    let toks = ids
        .iter()
        .map(|id| done.iter().find(|s| s.id == *id).expect("finished").tokens.clone())
        .collect();
    (toks, kv, report)
}

/// Block-boundary prompt lengths: one prompt per chunking class around the
/// default 16-token block ({b-1, b, b+1, 3b+5}), probed right after the
/// first generated token (so the spill holds a ragged tail block in the
/// paged arm). Token streams and spilled-KV bytes must match the
/// contiguous twin byte-for-byte — the block table is a layout
/// indirection, not a numerics change.
#[test]
fn block_boundary_prompts_match_contiguous() {
    let reg = registry();
    let b = DEFAULT_KV_BLOCK;
    for plen in [b - 1, b, b + 1, 3 * b + 5] {
        let prompt: Vec<usize> = (0..plen).map(|t| 9 + (t * 13) % 490).collect();
        let reqs = vec![(prompt, 6)];
        let (p_toks, p_kv, _) = run(&reg, paged_cfg(), 1, &reqs, 0, 1);
        let (c_toks, c_kv, _) = run(&reg, contiguous_cfg(), 1, &reqs, 0, 1);
        assert_eq!(p_toks, c_toks, "prompt {plen}: paged token stream diverged");
        assert!(!p_kv.is_empty(), "prompt {plen}: probe never fired");
        assert_eq!(p_kv, c_kv, "prompt {plen}: spilled-KV bytes diverged");
    }
}

/// Partial tail-block evict/hydrate through the detached-session API: a
/// session parked mid-generation at a position that only part-fills its
/// last block frees every resident block, keeps a contiguous-equivalent
/// host image, and resumes bit-identically.
#[test]
fn partial_tail_block_evicts_and_resumes_bit_identically() {
    let reg = registry();
    let b = DEFAULT_KV_BLOCK;
    // prompt (b + 5) + 3 steps parks at b + 8: one full block plus an
    // 8-row tail.
    let prompt: Vec<usize> = (0..b + 5).map(|t| 31 + (t * 7) % 450).collect();
    let tokens = 8;

    let drive = |se: &mut ServingEngine, s: &mut SessionState| {
        while !s.finished() {
            let (t, p) = s.take_input().unwrap();
            let h = se.encode_session(s, t, p).unwrap();
            se.finish_session(s, h).unwrap();
        }
        s.tokens.clone()
    };
    let spill_at = |cfg: EngineConfig| {
        let mut se =
            ServingEngine::new(&reg, ServeConfig { engine: cfg, max_concurrent: 1 }).unwrap();
        se.reseed(SEED);
        let mut s = se.create_session(prompt.clone(), tokens, 1);
        for _ in 0..prompt.len() + 3 {
            let (t, p) = s.take_input().unwrap();
            let h = se.encode_session(&mut s, t, p).unwrap();
            se.finish_session(&mut s, h).unwrap();
        }
        se.evict_session_cache(&mut s).unwrap();
        assert!(!s.kv.is_device(), "evicted session is host-resident");
        let host: Vec<Vec<u8>> = s
            .kv
            .as_host()
            .expect("spilled")
            .iter()
            .flat_map(|(k, v)| [k.data.as_bytes().to_vec(), v.data.as_bytes().to_vec()])
            .collect();
        let got = drive(&mut se, &mut s);
        (host, got)
    };

    let mut truth_se = ServingEngine::new(
        &reg,
        ServeConfig { engine: paged_cfg(), max_concurrent: 1 },
    )
    .unwrap();
    truth_se.reseed(SEED);
    let mut truth = truth_se.create_session(prompt.clone(), tokens, 9);
    let expect = drive(&mut truth_se, &mut truth);

    let (p_host, p_toks) = spill_at(paged_cfg());
    let (c_host, c_toks) = spill_at(contiguous_cfg());
    assert_eq!(p_toks, expect, "paged evict/re-hydrate changed the token stream");
    assert_eq!(c_toks, expect, "contiguous twin diverged");
    assert!(
        p_host.iter().any(|bytes| bytes.iter().any(|&x| x != 0)),
        "spilled cache must carry the session's context"
    );
    assert_eq!(
        p_host, c_host,
        "partial tail-block spill must reconstruct the contiguous image"
    );
}

/// Speculative rewind across block boundaries: with the smallest block
/// size (4 tokens) every multi-token draft straddles an edge, and
/// rejected drafts leave dead rows past the committed position in BOTH
/// layouts (the device scattered them before host-side verification).
/// Streams must match plain decode, and the mid-run spill must match the
/// contiguous speculative twin byte-for-byte — including the dead rows.
#[test]
fn speculative_rewind_across_block_boundary_matches_contiguous() {
    let reg = registry();
    // Repetitive prompt: the n-gram drafter gets real acceptances, so
    // accepted AND rejected drafts both cross 4-token block edges.
    let prompt: Vec<usize> = (0..9).map(|t| 40 + t % 3).collect();
    let reqs = vec![(prompt, 24)];
    let small = |speculate: usize, paged: bool| EngineConfig {
        kv_block: if paged { 4 } else { DEFAULT_KV_BLOCK },
        speculate,
        paged,
        ..paged_cfg()
    };
    let (ps_toks, ps_kv, ps_rep) = run(&reg, small(3, true), 1, &reqs, 0, 10);
    let (cs_toks, cs_kv, _) = run(&reg, small(3, false), 1, &reqs, 0, 10);
    let (pp_toks, _, _) = run(&reg, small(0, true), 1, &reqs, 0, 0);
    assert!(ps_rep.drafted > 0, "repetitive workload must actually draft");
    assert_eq!(ps_toks, pp_toks, "speculation changed the paged token stream");
    assert_eq!(ps_toks, cs_toks, "paged speculative stream diverged from contiguous");
    assert!(!ps_kv.is_empty(), "probe never fired");
    assert_eq!(
        ps_kv, cs_kv,
        "spilled-KV bytes after speculative rewind diverged (dead draft rows \
         must match the contiguous layout)"
    );
}

/// Dispatch census unchanged: the block table rides the existing uniform
/// upload path, so paged unified / split / interleaved rounds encode
/// exactly the contiguous dispatch counts (prefill and decode phases
/// alike).
#[test]
fn paged_dispatch_census_matches_contiguous() {
    let reg = registry();
    let reqs: Vec<(Vec<usize>, usize)> = [(33usize, 5usize), (16, 4), (7, 6), (50, 3)]
        .iter()
        .map(|&(plen, gen)| ((0..plen).map(|t| 17 + (t * 11) % 470).collect(), gen))
        .collect();
    let variants: [(&str, Box<dyn Fn(EngineConfig) -> EngineConfig>); 3] = [
        ("unified", Box::new(|c| c)),
        ("split", Box::new(|c| EngineConfig { unified: false, ..c })),
        (
            "interleaved",
            Box::new(|c| EngineConfig { batch_width: 0, prefill_chunk: 0, ..c }),
        ),
    ];
    for (label, make) in &variants {
        let (p_toks, _, p_rep) = run(&reg, make(paged_cfg()), 3, &reqs, 0, 0);
        let (c_toks, _, c_rep) = run(&reg, make(contiguous_cfg()), 3, &reqs, 0, 0);
        assert_eq!(p_toks, c_toks, "{label}: token streams diverged");
        assert_eq!(
            p_rep.dispatches, c_rep.dispatches,
            "{label}: paged rounds changed the dispatch census"
        );
        assert_eq!(
            p_rep.prefill_dispatches, c_rep.prefill_dispatches,
            "{label}: paged prefill changed the dispatch census"
        );
        assert_eq!(p_rep.rounds, c_rep.rounds, "{label}: round count diverged");
    }
}

/// The density headline (acceptance gate): at an equal pool cap of 4
/// contiguous sets, short sessions pay one 16-token block instead of a
/// full max_seq set, so >= 4x more sessions sit resident at peak than the
/// contiguous baseline — with identical token streams.
#[test]
fn paged_holds_4x_sessions_resident_at_equal_pool_cap() {
    let reg = registry();
    let cap = Some(4 * set_bytes());
    let reqs: Vec<(Vec<usize>, usize)> = (0..16)
        .map(|i| ((0..8).map(|t| 21 + (t * 5 + i * 29) % 460).collect(), 4))
        .collect();
    let capped = |paged: bool| EngineConfig {
        pool_cap_bytes: cap,
        paged,
        ..paged_cfg()
    };
    let (p_toks, _, p_rep) = run(&reg, capped(true), 16, &reqs, 0, 0);
    let (c_toks, _, c_rep) = run(&reg, capped(false), 16, &reqs, 0, 0);
    let (u_toks, _, _) = run(&reg, contiguous_cfg(), 16, &reqs, 0, 0);
    assert_eq!(p_toks, c_toks, "equal-cap paged vs contiguous streams diverged");
    assert_eq!(p_toks, u_toks, "capped streams diverged from uncapped");
    assert!(
        c_rep.resident_sessions_hw >= 1 && c_rep.resident_sessions_hw <= 4,
        "contiguous baseline must be capped at 4 resident sets, got {}",
        c_rep.resident_sessions_hw
    );
    assert!(
        p_rep.resident_sessions_hw >= 4 * c_rep.resident_sessions_hw,
        "paged must hold >= 4x sessions resident at equal cap: paged {} vs \
         contiguous {}",
        p_rep.resident_sessions_hw,
        c_rep.resident_sessions_hw
    );
    assert_eq!(p_rep.failed_sessions, 0);
    assert!(p_rep.kv_pool_high_water_groups >= 16, "one block per live session");
}

/// Graceful oversubscription (acceptance gate): sessions needing ~2.4x
/// the block budget of a one-set pool cap keep serving — admission
/// defers and the LRU pager spills cold blocks host-side (page-outs > 0,
/// page-ins > 0 as they come back) — and NOTHING fails. Streams stay
/// identical to the uncapped paged run and the contiguous baseline.
#[test]
fn oversubscribed_pool_pages_and_never_fails() {
    let reg = registry();
    let reqs: Vec<(Vec<usize>, usize)> = (0..8)
        .map(|i| ((0..40).map(|t| 13 + (t * 3 + i * 37) % 480).collect(), 8))
        .collect();
    let capped = EngineConfig {
        pool_cap_bytes: Some(set_bytes()), // 10 blocks; 8 sessions want 24
        ..paged_cfg()
    };
    let (o_toks, _, o_rep) = run(&reg, capped, 8, &reqs, 0, 0);
    let (p_toks, _, p_rep) = run(&reg, paged_cfg(), 8, &reqs, 0, 0);
    let (c_toks, _, _) = run(&reg, contiguous_cfg(), 8, &reqs, 0, 0);
    assert_eq!(o_toks, p_toks, "oversubscription changed the token streams");
    assert_eq!(p_toks, c_toks, "paged streams diverged from contiguous");
    assert_eq!(o_rep.failed_sessions, 0, "oversubscribed admission must never fail");
    assert_eq!(o_rep.sessions, 8, "every request completes");
    assert!(o_rep.kv_page_outs > 0, "a 2x-oversubscribed pool must page out");
    assert!(o_rep.kv_page_ins > 0, "paged-out blocks must come back");
    assert!(
        o_rep.kv_blocks_spilled_hw > 0,
        "some session must have held spilled blocks"
    );
    // The uncapped run never pages.
    assert_eq!(p_rep.kv_page_outs, 0);
    assert_eq!(p_rep.kv_page_ins, 0);
}

/// The paged report ledger self-describes: block size, group bytes, and
/// the `+paged(b=N)` mode label land in the report; the contiguous twin
/// stays unlabeled.
#[test]
fn report_carries_paged_ledger_and_mode_label() {
    let reg = registry();
    let reqs = vec![(vec![65usize, 66, 67], 4), (vec![70, 71], 4)];
    let (_, _, p_rep) = run(&reg, paged_cfg(), 2, &reqs, 0, 0);
    assert_eq!(p_rep.kv_block, DEFAULT_KV_BLOCK);
    let dims = GraphDims::qwen_tiny();
    assert_eq!(
        p_rep.kv_group_bytes as usize,
        2 * dims.layers * DEFAULT_KV_BLOCK * dims.kv_heads * dims.head_dim * 4
    );
    assert!(p_rep.kv_pool_high_water_groups > 0);
    assert!(p_rep.mode_label().contains("+paged(b=16)"), "{}", p_rep.mode_label());
    assert!(p_rep.kv_bytes_per_token() > 0.0);
    let (_, _, c_rep) = run(&reg, contiguous_cfg(), 2, &reqs, 0, 0);
    assert_eq!(c_rep.kv_block, 0);
    assert!(!c_rep.mode_label().contains("paged"), "{}", c_rep.mode_label());
}

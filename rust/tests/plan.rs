//! Planned-execution integration tests: bit-identical token parity with
//! eager execution across every executable workload x fusion x session
//! count, aliasing safety of the arena, allocation-free replay, the
//! bounded buffer pool, and plan-build vs replay attribution.

use wdb::engine::{Engine, EngineConfig, ExecMode};
use wdb::fx::builder::{build_decode_graph, FusionConfig, GraphDims};
use wdb::fx::workloads::decode_workloads;
use wdb::model::ByteTokenizer;
use wdb::runtime::Registry;
use wdb::serve::{ServeConfig, ServingEngine};

const SEED: u64 = 0x9141;

fn registry() -> Registry {
    Registry::builtin().expect("builtin registry")
}

fn cfg(dims: GraphDims, fusion: FusionConfig, exec: ExecMode) -> EngineConfig {
    EngineConfig {
        fusion,
        exec,
        dims_override: Some(dims),
        ..EngineConfig::tiny_fused()
    }
}

/// Run `sessions` identical-prompt requests and return each session's
/// token stream, in admission order.
fn run_sessions(
    reg: &Registry,
    config: EngineConfig,
    sessions: usize,
    prompt: &[usize],
    tokens: usize,
) -> Vec<Vec<usize>> {
    let mut se = ServingEngine::new(reg, ServeConfig { engine: config, max_concurrent: sessions })
        .expect("serving engine");
    se.reseed(SEED);
    for i in 0..sessions {
        // Vary prompts slightly so cross-session buffer reuse bugs show.
        let mut p = prompt.to_vec();
        p[0] = (p[0] + i) % 500;
        se.submit(&p, tokens).expect("submit");
    }
    se.run_to_completion().expect("serve");
    se.drain_finished().into_iter().map(|s| s.tokens).collect()
}

/// Acceptance: planned execution produces token streams bit-identical to
/// eager execution for every built-in workload (fused and unfused), at 1
/// and 4 concurrent sessions.
#[test]
fn planned_matches_eager_across_workloads_fusion_sessions() {
    let reg = registry();
    let prompt = vec![72usize, 101, 108];
    let tokens = 4;
    for wl in decode_workloads() {
        for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
            for sessions in [1usize, 4] {
                let eager = run_sessions(
                    &reg,
                    cfg(wl.dims, fusion, ExecMode::Eager),
                    sessions,
                    &prompt,
                    tokens,
                );
                let planned = run_sessions(
                    &reg,
                    cfg(wl.dims, fusion, ExecMode::Planned),
                    sessions,
                    &prompt,
                    tokens,
                );
                assert_eq!(
                    eager, planned,
                    "{} {:?} N={sessions}: planned diverged from eager",
                    wl.name, fusion
                );
            }
        }
    }
}

/// Planned mode with varying dispatches_per_submit still matches eager —
/// encoder batching is a pure scheduling transform.
#[test]
fn encoder_batching_preserves_tokens() {
    let reg = registry();
    let prompt = ByteTokenizer::new(512).paper_prompt();
    let dims = GraphDims::qwen_tiny();
    let mut base = Engine::new(&reg, cfg(dims, FusionConfig::fused(), ExecMode::Eager)).unwrap();
    let expect = base.generate(&prompt, 6).unwrap().tokens;
    for dps in [1usize, 2, 7, 64, 10_000] {
        let mut c = cfg(dims, FusionConfig::fused(), ExecMode::Planned);
        c.dispatches_per_submit = dps;
        let mut e = Engine::new(&reg, c).unwrap();
        let got = e.generate(&prompt, 6).unwrap().tokens;
        assert_eq!(got, expect, "dps={dps}");
    }
}

/// Batching N dispatches per encoder must reduce submits (the paper's
/// encoder-batching axis) without changing dispatch count.
#[test]
fn encoder_batching_reduces_submits() {
    let reg = registry();
    let prompt = vec![65usize];
    let dims = GraphDims::qwen_tiny();
    let run = |dps: usize| {
        let mut c = cfg(dims, FusionConfig::fused(), ExecMode::Planned);
        c.dispatches_per_submit = dps;
        let mut e = Engine::new(&reg, c).unwrap();
        let _ = e.generate(&prompt, 3).unwrap();
        (e.executor.device.stats.submits, e.executor.dispatch_count)
    };
    let (s1, d1) = run(1);
    let (s16, d16) = run(16);
    assert_eq!(d1, d16, "same dispatches either way");
    assert!(
        s16 * 8 < s1,
        "16 dispatches/submit must cut submits ~16x: {s16} vs {s1}"
    );
}

/// Aliasing safety: no two live value intervals share an arena slot.
#[test]
fn no_overlapping_intervals_share_an_arena_slot() {
    let reg = registry();
    for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
        let se = ServingEngine::new(
            &reg,
            ServeConfig {
                engine: cfg(GraphDims::qwen_tiny(), fusion, ExecMode::Planned),
                max_concurrent: 1,
            },
        )
        .unwrap();
        let plan = se.executor.plan().expect("planned engine has a plan");
        let a = &plan.arena.assignments;
        assert!(!a.is_empty());
        for (i, x) in a.iter().enumerate() {
            for y in &a[i + 1..] {
                if x.slot == y.slot {
                    assert!(
                        x.interval.disjoint(y.interval),
                        "{fusion:?}: values {} and {} share slot {} with \
                         overlapping intervals {:?} / {:?}",
                        x.value,
                        y.value,
                        x.slot,
                        x.interval,
                        y.interval
                    );
                }
            }
        }
        // Aliasing must actually save memory vs one-buffer-per-value.
        assert!(plan.stats.arena_bytes < plan.stats.unaliased_bytes, "{fusion:?}");
    }
}

/// The replay hot loop is resource-allocation-free: after the first
/// generate, further tokens create zero buffers and zero bind groups.
#[test]
fn planned_replay_creates_no_resources() {
    let reg = registry();
    let prompt = vec![66usize, 67];
    let mut e = Engine::new(
        &reg,
        cfg(GraphDims::qwen_tiny(), FusionConfig::fused(), ExecMode::Planned),
    )
    .unwrap();
    let _ = e.generate(&prompt, 2).unwrap();
    let bufs0 = e.executor.device.stats.buffers_created;
    let groups0 = e.executor.device.stats.bind_groups_created;
    let _ = e.generate(&prompt, 8).unwrap();
    assert_eq!(e.executor.device.stats.buffers_created, bufs0, "buffers leaked");
    assert_eq!(
        e.executor.device.stats.bind_groups_created, groups0,
        "bind groups created during replay"
    );
    assert_eq!(e.executor.device.stats.validation_errors, 0);
}

/// Eager mode's warmed bind-group cache also stops creating groups (the
/// no-alloc bind path satellite): steady-state steps are pure cache hits.
#[test]
fn eager_bind_cache_reaches_steady_state() {
    let reg = registry();
    let prompt = vec![70usize];
    let mut e = Engine::new(
        &reg,
        cfg(GraphDims::qwen_tiny(), FusionConfig::fused(), ExecMode::Eager),
    )
    .unwrap();
    let _ = e.generate(&prompt, 3).unwrap();
    let groups0 = e.executor.device.stats.bind_groups_created;
    let _ = e.generate(&prompt, 6).unwrap();
    assert_eq!(
        e.executor.device.stats.bind_groups_created, groups0,
        "steady-state eager steps must hit the bind-group cache"
    );
}

/// Planned framework overhead per op must be at least 2x below eager
/// (acceptance criterion; defaults give ~35x).
#[test]
fn planned_framework_overhead_at_least_2x_lower() {
    let reg = registry();
    let prompt = ByteTokenizer::new(512).paper_prompt();
    let fw_per_op = |exec: ExecMode| {
        let mut e =
            Engine::new(&reg, cfg(GraphDims::qwen_tiny(), FusionConfig::fused(), exec)).unwrap();
        e.reseed(SEED);
        let _ = e.generate(&prompt, 6).unwrap();
        e.executor.framework_virtual_ns as f64 / e.executor.dispatch_count.max(1) as f64
    };
    let eager = fw_per_op(ExecMode::Eager);
    let planned = fw_per_op(ExecMode::Planned);
    assert!(
        eager >= 2.0 * planned,
        "planned framework/op {planned} not >= 2x below eager {eager}"
    );
}

/// Plan-build cost is attributed separately from replay cost.
#[test]
fn plan_build_vs_replay_attribution() {
    let reg = registry();
    let prompt = vec![65usize, 66];
    let mut se = ServingEngine::new(
        &reg,
        ServeConfig { engine: EngineConfig::tiny_planned(), max_concurrent: 1 },
    )
    .unwrap();
    se.submit(&prompt, 3).unwrap();
    let report = se.run_to_completion().unwrap();
    assert!(report.planned);
    assert!(report.plan_build_virtual_ns > 0, "bind-group creation is build cost");
    assert!(report.plan_build_real_ns > 0);
    assert!(report.encode_virtual_ns > 0, "replay cost attributed per session");
    // Eager runs report no build cost.
    let mut se2 = ServingEngine::new(
        &reg,
        ServeConfig { engine: EngineConfig::tiny_fused(), max_concurrent: 1 },
    )
    .unwrap();
    se2.submit(&prompt, 3).unwrap();
    let r2 = se2.run_to_completion().unwrap();
    assert!(!r2.planned);
    assert_eq!(r2.plan_build_virtual_ns, 0);
}

/// Bounded pool: a tiny cap fails fast instead of growing silently; a
/// generous cap reports high-water/creation stats in the serving report.
#[test]
fn pool_cap_errors_and_stats_surface() {
    let reg = registry();
    let mut small = EngineConfig::tiny_fused();
    small.pool_cap_bytes = Some(1024); // far below one decode step's needs
    let mut e = Engine::new(&reg, small).unwrap();
    let err = e.generate(&[65], 2);
    assert!(err.is_err(), "tiny pool cap must error, got {err:?}");

    let mut big = EngineConfig::tiny_fused();
    big.pool_cap_bytes = Some(64 << 20);
    let mut se =
        ServingEngine::new(&reg, ServeConfig { engine: big, max_concurrent: 2 }).unwrap();
    se.submit(&[65, 66], 3).unwrap();
    se.submit(&[70, 71], 3).unwrap();
    let report = se.run_to_completion().unwrap();
    assert!(report.pool_high_water_bytes > 0);
    assert!(report.pool_buffers_created > 0);
    assert!(report.pool_high_water_bytes <= 64 << 20);
}

/// Planned serving still amortizes the per-round sync and keeps the
/// N-session token streams independent (ring isolation).
#[test]
fn planned_sessions_are_ring_isolated() {
    let reg = registry();
    let tokens = 5;
    let prompts: Vec<Vec<usize>> = vec![vec![65, 66], vec![90, 91, 92], vec![120], vec![33, 34]];
    // Sequential single-session truth.
    let mut expect = Vec::new();
    for p in &prompts {
        let mut e = Engine::new(&reg, EngineConfig::tiny_planned()).unwrap();
        expect.push(e.generate(p, tokens).unwrap().tokens);
    }
    // Interleaved 4-session run over ONE shared plan.
    let mut se = ServingEngine::new(
        &reg,
        ServeConfig { engine: EngineConfig::tiny_planned(), max_concurrent: 4 },
    )
    .unwrap();
    let mut ids = Vec::new();
    for p in &prompts {
        ids.push(se.submit(p, tokens).unwrap());
    }
    se.run_to_completion().unwrap();
    let done = se.drain_finished();
    for (i, id) in ids.iter().enumerate() {
        let s = done.iter().find(|s| s.id == *id).expect("finished");
        assert_eq!(
            s.tokens, expect[i],
            "session {i} corrupted by shared-plan interleaving"
        );
    }
}

/// Public encode/finish API with overlapping deferred readbacks: two
/// sessions encoded back-to-back before either finishes must land in
/// distinct logits-ring buffers (the ring cursor), not clobber each other.
#[test]
fn public_encode_finish_interleave_is_ring_safe() {
    let reg = registry();
    // Sequential single-session truth.
    let mut ea = Engine::new(&reg, EngineConfig::tiny_planned()).unwrap();
    let ta = ea.generate(&[65], 3).unwrap().tokens;
    let mut eb = Engine::new(&reg, EngineConfig::tiny_planned()).unwrap();
    let tb = eb.generate(&[90], 3).unwrap().tokens;

    let mut se = ServingEngine::new(
        &reg,
        ServeConfig { engine: EngineConfig::tiny_planned(), max_concurrent: 2 },
    )
    .unwrap();
    let mut a = se.create_session(vec![65], 3, 10);
    let mut b = se.create_session(vec![90], 3, 11);
    while !(a.finished() && b.finished()) {
        // Both encodes outstanding before either finish: the deferred
        // logits readbacks overlap.
        let (tok_a, pa) = a.take_input().expect("a input");
        let ha = se.encode_session(&mut a, tok_a, pa).unwrap();
        let (tok_b, pb) = b.take_input().expect("b input");
        let hb = se.encode_session(&mut b, tok_b, pb).unwrap();
        se.finish_session(&mut a, ha).unwrap();
        se.finish_session(&mut b, hb).unwrap();
    }
    assert_eq!(a.tokens, ta, "session A clobbered by overlapping encode");
    assert_eq!(b.tokens, tb, "session B clobbered by overlapping encode");
}

/// The planner rejects nothing the builder emits: every fusion preset of
/// every workload compiles and the plan step count matches the graph.
#[test]
fn every_preset_compiles_to_a_plan() {
    let reg = registry();
    for wl in decode_workloads() {
        for fusion in [
            FusionConfig::unfused(),
            FusionConfig::rmsnorm_only(),
            FusionConfig::rmsnorm_mlp(),
            FusionConfig::rmsnorm_mlp_kv(),
            FusionConfig::fused(),
        ] {
            let se = ServingEngine::new(
                &reg,
                ServeConfig {
                    engine: cfg(wl.dims, fusion, ExecMode::Planned),
                    max_concurrent: 1,
                },
            )
            .unwrap_or_else(|e| panic!("{} {fusion:?}: {e}", wl.name));
            let g = build_decode_graph(&wl.dims, fusion);
            let plan = se.executor.plan().unwrap();
            assert_eq!(plan.stats.kernel_steps, g.dispatch_count(), "{} {fusion:?}", wl.name);
        }
    }
}

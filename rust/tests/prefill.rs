//! Chunked-prefill integration tests: bit-identical equivalence between
//! chunked prompt ingestion and token-by-token prefill across chunk sizes
//! x prompt lengths x fusion configs (including byte-identical KV cache
//! state), ragged-tail masking without recompiles, interleaving with
//! batched decode rounds, the per-session attribution invariants, and the
//! dispatch-collapse acceptance gate at prompt 128.
//!
//! Everything runs against the built-in manifest + host reference runtime,
//! so the suite is hermetic and deterministic.

use wdb::engine::{EngineConfig, ExecMode};
use wdb::fx::builder::{FusionConfig, PREFILL_CHUNKS};
use wdb::runtime::Registry;
use wdb::serve::{ServeConfig, ServeReport, ServingEngine};

const SEED: u64 = 0xCF111;

fn registry() -> Registry {
    Registry::builtin().expect("builtin registry")
}

fn cfg(fusion: FusionConfig, prefill_chunk: usize) -> EngineConfig {
    EngineConfig {
        fusion,
        exec: ExecMode::Planned,
        prefill_chunk,
        ..EngineConfig::tiny_fused()
    }
}

/// Deterministic prompt of `len` tokens inside the tiny vocab.
fn prompt_of(len: usize) -> Vec<usize> {
    (0..len).map(|i| 33 + (i * 11) % 400).collect()
}

/// Run one session to completion; return (tokens, report).
fn run_one(
    reg: &Registry,
    config: EngineConfig,
    prompt: &[usize],
    tokens: usize,
) -> (Vec<usize>, ServeReport) {
    let mut se = ServingEngine::new(reg, ServeConfig { engine: config, max_concurrent: 1 })
        .expect("serving engine");
    se.reseed(SEED);
    se.submit(prompt, tokens).expect("submit");
    let report = se.run_to_completion().expect("serve");
    let mut done = se.drain_finished();
    (done.remove(0).tokens, report)
}

/// Acceptance: chunked prefill is bit-identical to token-by-token prompt
/// ingestion across the full equivalence matrix — chunk {8, 16, 32} x
/// prompt lengths {1, C-1, C, C+1, 3C+5} x {fused, unfused}. Identical
/// token streams mean identical logits at every read-back position (the
/// argmax is a pure function of the logits bytes); the KV-cache byte
/// check below pins the state side.
#[test]
fn chunked_prefill_matches_token_by_token_across_matrix() {
    let reg = registry();
    let tokens = 2;
    for chunk in PREFILL_CHUNKS {
        for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
            for plen in [1, chunk - 1, chunk, chunk + 1, 3 * chunk + 5] {
                let prompt = prompt_of(plen);
                let (tbt, tbt_rep) = run_one(&reg, cfg(fusion, 0), &prompt, tokens);
                let (chunked, ch_rep) = run_one(&reg, cfg(fusion, chunk), &prompt, tokens);
                assert_eq!(
                    chunked, tbt,
                    "{fusion:?} chunk {chunk} prompt {plen}: chunked prefill \
                     diverged from token-by-token"
                );
                // Step accounting stays token-granular in both modes.
                assert_eq!(ch_rep.steps, tbt_rep.steps, "chunk {chunk} prompt {plen}");
                assert_eq!(ch_rep.prefill_steps, plen as u64);
                assert_eq!(tbt_rep.prefill_steps, plen as u64);
                // Chunked prompt ingestion never issues MORE dispatches —
                // except the degenerate 1-token prompt, where the chunk's
                // extra last-row selection makes it 60 vs 59.
                if plen >= 2 {
                    assert!(
                        ch_rep.prefill_dispatches <= tbt_rep.prefill_dispatches,
                        "chunk {chunk} prompt {plen}: {} > {}",
                        ch_rep.prefill_dispatches,
                        tbt_rep.prefill_dispatches
                    );
                }
            }
        }
    }
}

/// The KV cache a chunked prefill scatters is BYTE-identical to the state
/// token-by-token ingestion accumulates: drive both engines to the first
/// generated token, spill both sessions' device caches, and compare every
/// layer's K/V bytes.
#[test]
fn prefill_kv_cache_bytes_identical_to_token_by_token() {
    let reg = registry();
    let chunk = 8usize;
    for fusion in [FusionConfig::unfused(), FusionConfig::fused()] {
        for plen in [chunk - 1, chunk, chunk + 1, 3 * chunk + 5] {
            let prompt = prompt_of(plen);
            let spill = |prefill_chunk: usize| {
                let mut se = ServingEngine::new(
                    &reg,
                    ServeConfig { engine: cfg(fusion, prefill_chunk), max_concurrent: 1 },
                )
                .unwrap();
                se.reseed(SEED);
                se.submit(&prompt, 2).unwrap();
                // Step until the first generated token exists; the session
                // stays active (it still owes one more token).
                while se.active.is_empty() || se.active[0].tokens.is_empty() {
                    se.step_round().unwrap();
                }
                let mut s = se.active.remove(0);
                assert_eq!(s.pos, plen, "prefill must land exactly plen cache rows");
                se.evict_session_cache(&mut s).unwrap();
                let host = s.kv.as_host().expect("spilled").clone();
                (s.tokens.clone(), host)
            };
            let (t_tbt, kv_tbt) = spill(0);
            let (t_ch, kv_ch) = spill(chunk);
            assert_eq!(t_ch, t_tbt, "{fusion:?} prompt {plen}: first token diverged");
            assert_eq!(kv_ch.len(), kv_tbt.len());
            for (l, ((kc, vc), (kt, vt))) in kv_ch.iter().zip(&kv_tbt).enumerate() {
                assert_eq!(
                    kc.data.as_bytes(),
                    kt.data.as_bytes(),
                    "{fusion:?} prompt {plen} layer {l}: K cache bytes diverged"
                );
                assert_eq!(
                    vc.data.as_bytes(),
                    vt.data.as_bytes(),
                    "{fusion:?} prompt {plen} layer {l}: V cache bytes diverged"
                );
            }
        }
    }
}

/// Acceptance gate: at prompt 128 with chunk 16, chunked prefill issues
/// at most 1/4 the prompt-ingestion dispatches of token-by-token (it
/// actually issues ~1/15: 8 chunk replays of ~60 dispatches vs 128 steps
/// of 59), with an identical token stream and a self-describing report.
#[test]
fn prefill_dispatch_gate_at_prompt_128() {
    let reg = registry();
    let prompt = prompt_of(128);
    let tokens = 16;
    let (tbt, tbt_rep) = run_one(&reg, cfg(FusionConfig::fused(), 0), &prompt, tokens);
    let (chunked, ch_rep) = run_one(&reg, cfg(FusionConfig::fused(), 16), &prompt, tokens);
    assert_eq!(chunked, tbt, "prompt-128 token streams diverged");
    assert!(
        ch_rep.prefill_dispatches * 4 <= tbt_rep.prefill_dispatches,
        "gate: chunked {} prefill dispatches !<= token-by-token {} / 4",
        ch_rep.prefill_dispatches,
        tbt_rep.prefill_dispatches
    );
    // ~60 dispatches per 16-token chunk vs 59 per token.
    assert!(ch_rep.prefill_dispatches_per_prompt_token() < 5.0);
    assert!(tbt_rep.prefill_dispatches_per_prompt_token() > 50.0);
    // The dispatch collapse shows up as TTFT: prompt ingestion is the
    // dominant pre-first-token cost at prompt 128.
    assert!(
        ch_rep.mean_ttft_ms < tbt_rep.mean_ttft_ms,
        "chunked TTFT {:.2} ms !< token-by-token {:.2} ms",
        ch_rep.mean_ttft_ms,
        tbt_rep.mean_ttft_ms
    );
    assert!(ch_rep.mean_prefill_ms < tbt_rep.mean_prefill_ms);
    // TTFT attribution splits: both components present and ordered.
    assert!(ch_rep.mean_prefill_ms > 0.0 && ch_rep.mean_first_decode_ms > 0.0);
    // Self-describing report (the serve header satellite).
    assert_eq!(ch_rep.prefill_chunk, 16);
    assert!(ch_rep.mode_label().contains("prefill(c=16)"), "{}", ch_rep.mode_label());
    assert_eq!(tbt_rep.prefill_chunk, 0);
}

/// Ragged final chunks replay the SAME plan: `valid_len` masks the tail,
/// so a prompt that is not a chunk multiple creates no pipelines beyond
/// engine construction and replays exactly ceil(plen / C) chunks.
#[test]
fn ragged_tail_chunks_reuse_the_plan_without_recompile() {
    let reg = registry();
    let chunk = 8usize;
    let prompt = prompt_of(11); // one full chunk + a 3-row ragged tail
    let (tbt, _) = run_one(&reg, cfg(FusionConfig::fused(), 0), &prompt, 3);

    let mut se = ServingEngine::new(
        &reg,
        ServeConfig { engine: cfg(FusionConfig::fused(), chunk), max_concurrent: 1 },
    )
    .unwrap();
    se.reseed(SEED);
    se.submit(&prompt, 3).unwrap();
    let pipes0 = se.executor.device.stats.pipelines_created;
    se.run_to_completion().unwrap();
    assert_eq!(
        se.executor.device.stats.pipelines_created, pipes0,
        "ragged tail chunks must not recompile"
    );
    let runner = se.executor.prefill_runner().expect("prefill plan enabled");
    assert_eq!(runner.chunks, 2, "ceil(11 / 8) chunk replays");
    assert_eq!(runner.chunk(), chunk);
    let got: Vec<Vec<usize>> = se.drain_finished().into_iter().map(|s| s.tokens).collect();
    assert_eq!(got[0], tbt, "ragged-tail stream diverged");
}

/// Continuous batching: a long-prompt session ingests chunks while
/// already-generating sessions decode through BATCHED rounds in the same
/// scheduler rounds — and every stream still matches the token-by-token
/// engine exactly.
#[test]
fn prefill_interleaves_with_batched_decode_rounds() {
    let reg = registry();
    let run = |prefill_chunk: usize| -> Vec<Vec<usize>> {
        let mut se = ServingEngine::new(
            &reg,
            ServeConfig {
                engine: cfg(FusionConfig::fused(), prefill_chunk),
                max_concurrent: 3,
            },
        )
        .unwrap();
        se.reseed(SEED);
        // A's 40-token prompt takes ceil(40/16) = 3 chunked rounds, during
        // which B and C (1- and 2-token prompts) are already decoding —
        // as a 2-session batched chunk when chunking is on.
        let ida = se.submit(&prompt_of(40), 4).unwrap();
        let idb = se.submit(&[90], 12).unwrap();
        let idc = se.submit(&[120, 121], 10).unwrap();
        se.run_to_completion().unwrap();
        let done = se.drain_finished();
        [ida, idb, idc]
            .iter()
            .map(|id| done.iter().find(|s| s.id == *id).unwrap().tokens.clone())
            .collect()
    };
    assert_eq!(
        run(16),
        run(0),
        "mixed prefill/decode rounds diverged from token-by-token serving"
    );
}

/// Per-session attribution keeps tiling the engine totals through mixed
/// prefill/decode rounds, and step accounting stays token-granular
/// (a C-token chunk counts C prompt steps).
#[test]
fn prefill_attribution_tiles_engine_totals() {
    let reg = registry();
    let mut se = ServingEngine::new(
        &reg,
        ServeConfig { engine: cfg(FusionConfig::fused(), 16), max_concurrent: 2 },
    )
    .unwrap();
    se.reseed(SEED);
    se.submit(&prompt_of(20), 3).unwrap();
    se.submit(&prompt_of(3), 3).unwrap();
    let report = se.run_to_completion().unwrap();
    let done = se.drain_finished();
    let dispatches: u64 = done.iter().map(|s| s.metrics.dispatches).sum();
    assert_eq!(
        dispatches, se.executor.dispatch_count,
        "per-session dispatch shares must tile the engine total"
    );
    let fw: u64 = done.iter().map(|s| s.metrics.framework_virtual_ns).sum();
    assert_eq!(fw, se.executor.framework_virtual_ns, "framework attribution");
    let sync: u64 = done.iter().map(|s| s.metrics.sync_virtual_ns).sum();
    assert_eq!(
        sync, se.executor.device.timeline.sync_virtual_ns,
        "sync attribution (intermediate chunks never synchronize)"
    );
    // Token-granular steps: prompt + generated - 1 per session.
    for s in &done {
        assert_eq!(
            s.metrics.steps,
            (s.prompt.len() + s.n_new - 1) as u64,
            "session {}",
            s.id
        );
        assert_eq!(s.metrics.prefill_steps, s.prompt.len() as u64);
        assert!(s.metrics.prefill_end_ns >= s.metrics.admitted_ns);
        assert!(s.metrics.first_token_ns >= s.metrics.prefill_end_ns);
    }
    assert_eq!(report.prefill_steps, 23);
}

/// Chunked prefill never engages for eager mode, the device-argmax finish
/// variant, or `--prefill-chunk 0`; a chunk size outside the built-in
/// kernel coverage fails loudly at construction.
#[test]
fn prefill_gates_on_mode_chunk_and_argmax() {
    let reg = registry();
    let eager = ServingEngine::new(
        &reg,
        ServeConfig {
            engine: EngineConfig { prefill_chunk: 16, ..EngineConfig::tiny_fused() },
            max_concurrent: 2,
        },
    )
    .unwrap();
    assert!(eager.prefill_graph.is_none(), "eager engines must not chunk prefill");
    assert_eq!(eager.prefill_chunk, 0);

    let argmax = ServingEngine::new(
        &reg,
        ServeConfig {
            engine: EngineConfig {
                exec: ExecMode::Planned,
                device_argmax: true,
                ..EngineConfig::tiny_fused()
            },
            max_concurrent: 2,
        },
    )
    .unwrap();
    assert!(argmax.prefill_graph.is_none(), "device-argmax engines must not chunk");

    let disabled = ServingEngine::new(
        &reg,
        ServeConfig { engine: cfg(FusionConfig::fused(), 0), max_concurrent: 2 },
    )
    .unwrap();
    assert!(disabled.prefill_graph.is_none(), "--prefill-chunk 0 must disable");

    for bad in [5usize, 64] {
        let err = ServingEngine::new(
            &reg,
            ServeConfig { engine: cfg(FusionConfig::fused(), bad), max_concurrent: 2 },
        );
        assert!(err.is_err(), "chunk {bad} has no kernel coverage and must error");
    }

    for good in PREFILL_CHUNKS {
        let se = ServingEngine::new(
            &reg,
            ServeConfig { engine: cfg(FusionConfig::fused(), good), max_concurrent: 1 },
        )
        .unwrap();
        assert_eq!(se.prefill_chunk, good);
        assert!(se.prefill_graph.is_some());
        assert_eq!(
            se.executor.prefill_runner().expect("materialized").chunk(),
            good
        );
    }
}

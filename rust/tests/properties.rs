//! Property-based tests over coordinator invariants.
//!
//! proptest is unavailable in the offline build, so these use the in-tree
//! seeded generator (`XorShiftRng`) with wide randomized sweeps — same
//! spirit: each test states an invariant and hammers it with generated
//! cases; failures print the offending seed.

use wdb::engine::EngineConfig;
use wdb::fx::builder::{build_decode_graph, expected_dispatches, FusionConfig, GraphDims};
use wdb::runtime::Registry;
use wdb::serve::{RequestQueue, ServeConfig, ServingEngine};
use wdb::fx::census::Census;
use wdb::fx::fusion;
use wdb::model::rng::XorShiftRng;
use wdb::report::json::{self, Value};
use wdb::stats::{summarize, t_critical_975, welch_t_test};
use wdb::stats::welch::t_p_value;
use wdb::tensor::Tensor;
use wdb::webgpu::clock::{Jitter, VirtualClock};
use wdb::webgpu::profile::PhaseCosts;
use wdb::webgpu::ImplementationProfile;

// ------------------------------------------------------------- census ----
#[test]
fn census_identities_hold_for_all_layer_counts() {
    for layers in 1..=96 {
        let dims = GraphDims {
            layers,
            ..GraphDims::qwen25_05b()
        };
        let c = Census::for_dims(&dims);
        // compute total follows 36L + 12
        assert_eq!(c.compute.total(), 36 * layers + 12, "L={layers}");
        // node total is the sum of its parts
        assert_eq!(
            c.total_nodes(),
            c.compute.total() + c.shape_ops + c.placeholders_outputs + c.metadata
        );
        // fused is strictly fewer and positive
        assert!(c.fused_dispatches() < c.unfused_dispatches());
        assert!(c.fused_dispatches() > 0);
        // savings = rmsnorm + mlp + kv = 13L
        assert_eq!(c.paper_fusion_savings().total(), 13 * layers);
    }
}

// ---------------------------------------------------------- fx builder ----
#[test]
fn decode_graphs_validate_for_random_architectures() {
    let mut rng = XorShiftRng::new(0xF00D);
    for trial in 0..40 {
        let head_dim = [8, 16, 32][rng.below(3)];
        let kv_heads = [1, 2, 4][rng.below(3)];
        let group = 1 + rng.below(4);
        let dims = GraphDims {
            hidden: kv_heads * group * head_dim,
            layers: 1 + rng.below(8),
            heads: kv_heads * group,
            kv_heads,
            head_dim,
            intermediate: 16 * (1 + rng.below(12)),
            vocab: 256 + 16 * rng.below(32),
            max_seq: 32,
            tiny_names: true,
        };
        for fusion_cfg in [
            FusionConfig::unfused(),
            FusionConfig::rmsnorm_only(),
            FusionConfig::rmsnorm_mlp(),
            FusionConfig::rmsnorm_mlp_kv(),
            FusionConfig::fused(),
        ] {
            let g = build_decode_graph(&dims, fusion_cfg);
            g.validate()
                .unwrap_or_else(|e| panic!("trial {trial} {dims:?} {fusion_cfg:?}: {e}"));
            assert_eq!(
                g.dispatch_count(),
                expected_dispatches(&dims, fusion_cfg),
                "trial {trial} {fusion_cfg:?}"
            );
        }
    }
}

#[test]
fn fusion_passes_preserve_ssa_and_reduce_dispatches() {
    let mut rng = XorShiftRng::new(0xFA57);
    for _ in 0..20 {
        let dims = GraphDims {
            layers: 1 + rng.below(6),
            ..GraphDims::qwen_tiny()
        };
        let g = build_decode_graph(&dims, FusionConfig::unfused());
        let f = fusion::fuse_all(&g, "tiny");
        f.validate().expect("fused graph must stay valid SSA");
        assert!(f.dispatch_count() < g.dispatch_count());
        // Passes reach exactly the builder's fully-fused count.
        let direct = build_decode_graph(&dims, FusionConfig::fused());
        assert_eq!(f.dispatch_count(), direct.dispatch_count());
        // Outputs preserved.
        assert_eq!(f.outputs.len(), g.outputs.len());
    }
}

// ------------------------------------------------------------- clock ----
#[test]
fn virtual_clock_is_monotone_under_random_ops() {
    let mut rng = XorShiftRng::new(0xC10C);
    for _ in 0..50 {
        let mut c = VirtualClock::new();
        let mut last_cpu = 0;
        for _ in 0..200 {
            match rng.below(3) {
                0 => c.advance_cpu(rng.below(10_000) as u64),
                1 => {
                    c.enqueue_gpu(rng.below(10_000) as u64);
                }
                _ => c.sync(rng.below(1_000) as u64),
            }
            assert!(c.cpu_ns >= last_cpu, "cpu clock went backwards");
            last_cpu = c.cpu_ns;
            assert!(c.gpu_busy_ns <= c.gpu_done_ns.max(c.cpu_ns) + c.gpu_busy_ns);
        }
        // After a final sync the CPU is at/past the GPU frontier.
        c.sync(0);
        assert!(c.cpu_ns >= c.gpu_done_ns);
    }
}

#[test]
fn jitter_stays_in_band_for_random_bases() {
    let mut rng = XorShiftRng::new(0x7177);
    let mut j = Jitter::new(0x1234);
    for _ in 0..500 {
        let base = rng.below(1_000_000) as u64;
        let pct = rng.uniform() * 0.5;
        let v = j.apply(base, pct);
        let lo = (base as f64 * (1.0 - pct) - 1.0).max(0.0);
        let hi = base as f64 * (1.0 + pct) + 1.0;
        assert!(
            (v as f64) >= lo && (v as f64) <= hi,
            "jitter {v} outside [{lo}, {hi}] for base {base} pct {pct}"
        );
    }
}

#[test]
fn phase_costs_preserve_total_for_random_values() {
    let mut rng = XorShiftRng::new(0xFACE);
    for _ in 0..500 {
        let total = rng.below(10_000_000) as u64;
        let pc = PhaseCosts::from_total(total);
        assert_eq!(pc.total(), total, "total {total}");
    }
}

// -------------------------------------------------------------- stats ----
#[test]
fn ci_contains_mean_for_random_samples() {
    let mut rng = XorShiftRng::new(0x57A7);
    for _ in 0..100 {
        let n = 2 + rng.below(50);
        let mu = rng.uniform_in(-100.0, 100.0);
        let sigma = rng.uniform_in(0.01, 10.0);
        let xs: Vec<f64> = (0..n).map(|_| mu + sigma * rng.normal()).collect();
        let s = summarize(&xs);
        assert!(s.ci95_lo <= s.mean && s.mean <= s.ci95_hi);
        assert!(s.std >= 0.0);
    }
}

#[test]
fn welch_p_is_symmetric_and_bounded() {
    let mut rng = XorShiftRng::new(0x3E1C);
    for _ in 0..100 {
        let na = 3 + rng.below(20);
        let nb = 3 + rng.below(20);
        let a: Vec<f64> = (0..na).map(|_| rng.normal() * 2.0 + 1.0).collect();
        let b: Vec<f64> = (0..nb).map(|_| rng.normal() * 3.0 - 1.0).collect();
        let ab = welch_t_test(&a, &b);
        let ba = welch_t_test(&b, &a);
        assert!((0.0..=1.0).contains(&ab.p), "p {}", ab.p);
        assert!((ab.p - ba.p).abs() < 1e-9, "asymmetric p");
        assert!((ab.t + ba.t).abs() < 1e-9, "t not antisymmetric");
    }
}

#[test]
fn t_p_value_monotone_in_t() {
    for df in [2.0, 5.0, 10.0, 29.0, 100.0] {
        let mut last = 1.0 + 1e-12;
        for i in 0..60 {
            let t = i as f64 * 0.25;
            let p = t_p_value(t, df);
            assert!(p <= last + 1e-12, "p not decreasing at t={t}, df={df}");
            last = p;
        }
    }
}

#[test]
fn t_critical_monotone_decreasing_in_df() {
    let mut last = f64::INFINITY;
    for df in 1..200 {
        let t = t_critical_975(df as f64);
        assert!(t <= last + 1e-9, "t_crit not decreasing at df={df}");
        assert!(t >= 1.9);
        last = t;
    }
}

// --------------------------------------------------------------- json ----
fn random_json(rng: &mut XorShiftRng, depth: usize) -> Value {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Value::Null,
        1 => Value::Bool(rng.below(2) == 0),
        2 => Value::Num((rng.uniform_in(-1e6, 1e6) * 100.0).round() / 100.0),
        3 => {
            let len = rng.below(12);
            let s: String = (0..len)
                .map(|_| {
                    let c = rng.below(96) as u8 + 32;
                    c as char
                })
                .collect();
            Value::Str(s)
        }
        4 => Value::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => {
            let mut m = std::collections::BTreeMap::new();
            for i in 0..rng.below(5) {
                m.insert(format!("k{i}"), random_json(rng, depth - 1));
            }
            Value::Obj(m)
        }
    }
}

#[test]
fn json_roundtrips_random_documents() {
    let mut rng = XorShiftRng::new(0x1507);
    for trial in 0..200 {
        let v = random_json(&mut rng, 3);
        let compact = json::to_string(&v);
        let pretty = json::to_string_pretty(&v);
        assert_eq!(json::parse(&compact).unwrap(), v, "trial {trial}: {compact}");
        assert_eq!(json::parse(&pretty).unwrap(), v, "trial {trial}");
    }
}

// ------------------------------------------------------------- tensor ----
#[test]
fn tensor_slice_concat_identity() {
    let mut rng = XorShiftRng::new(0x7E50);
    for _ in 0..100 {
        let rows = 1 + rng.below(6);
        let cols = 2 * (1 + rng.below(16));
        let data = rng.normal_vec_f32(rows * cols, 1.0);
        let t = Tensor::f32(vec![rows, cols], data.clone()).unwrap();
        let a = t.slice_last_2d(0, cols / 2).unwrap();
        let b = t.slice_last_2d(cols / 2, cols).unwrap();
        // splicing halves back reproduces the rows
        for r in 0..rows {
            let row: Vec<f32> = a.as_f32().unwrap()[r * cols / 2..(r + 1) * cols / 2]
                .iter()
                .chain(&b.as_f32().unwrap()[r * cols / 2..(r + 1) * cols / 2])
                .copied()
                .collect();
            assert_eq!(&row, &data[r * cols..(r + 1) * cols]);
        }
    }
}

#[test]
fn tensor_argmax_agrees_with_scan() {
    let mut rng = XorShiftRng::new(0xA93A);
    for _ in 0..100 {
        let n = 1 + rng.below(2000);
        let data = rng.normal_vec_f32(n, 5.0);
        let t = Tensor::f32(vec![1, n], data.clone()).unwrap();
        let got = t.argmax_row().unwrap();
        let want = data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))
            .unwrap()
            .0;
        assert_eq!(got, want);
    }
}

// ------------------------------------------------------------- serving ----
/// Under randomly-sized interleaved multi-session runs, the shared
/// VirtualClock must stay monotone round-over-round, and the per-session
/// attribution must tile the device's PhaseTimeline exactly: every phase,
/// the sync total, the framework total, and the dispatch count each equal
/// the sum over sessions (nothing double-counted, nothing lost).
#[test]
fn multi_session_attribution_tiles_device_timeline() {
    let reg = Registry::builtin().unwrap();
    let mut rng = XorShiftRng::new(0x5E21);
    for trial in 0..6 {
        let max_concurrent = 1 + rng.below(3);
        let n_requests = 1 + rng.below(4);
        let mut se = ServingEngine::new(
            &reg,
            ServeConfig { engine: EngineConfig::tiny_fused(), max_concurrent },
        )
        .unwrap();
        se.reseed(0xA110 + trial as u64);
        for _ in 0..n_requests {
            let plen = 1 + rng.below(3);
            let prompt: Vec<usize> = (0..plen).map(|_| 32 + rng.below(200)).collect();
            se.submit(&prompt, 1 + rng.below(3)).unwrap();
        }
        let mut last_now = se.now_ns();
        loop {
            let stepped = se.step_round().unwrap();
            let now = se.now_ns();
            assert!(now >= last_now, "trial {trial}: clock went backwards");
            last_now = now;
            if stepped == 0 {
                break;
            }
        }
        let done = se.drain_finished();
        assert_eq!(done.len(), n_requests, "trial {trial}");

        let tl = &se.executor.device.timeline;
        for i in 0..8 {
            let attributed: u64 = done.iter().map(|s| s.metrics.phase_virtual_ns[i]).sum();
            assert_eq!(
                attributed, tl.virtual_ns[i],
                "trial {trial}: phase {i} attribution {attributed} != timeline {}",
                tl.virtual_ns[i]
            );
        }
        let sync: u64 = done.iter().map(|s| s.metrics.sync_virtual_ns).sum();
        assert_eq!(sync, tl.sync_virtual_ns, "trial {trial}: sync attribution");
        let kernel: u64 = done.iter().map(|s| s.metrics.kernel_virtual_ns).sum();
        assert_eq!(kernel, tl.kernel_virtual_ns, "trial {trial}: kernel attribution");
        let fw: u64 = done.iter().map(|s| s.metrics.framework_virtual_ns).sum();
        assert_eq!(fw, se.executor.framework_virtual_ns, "trial {trial}: framework");
        let dispatches: u64 = done.iter().map(|s| s.metrics.dispatches).sum();
        assert_eq!(dispatches, se.executor.dispatch_count, "trial {trial}: dispatches");
        assert_eq!(dispatches, tl.dispatches(), "trial {trial}: timeline dispatches");
        // Phase-sum invariant: totals are the sum of their parts.
        assert_eq!(tl.total_virtual_ns(), tl.virtual_ns.iter().sum::<u64>());
    }
}

/// FIFO admission-order invariants under arbitrary arrival/completion
/// interleavings: the set of admitted ids is always a prefix of the
/// arrival order, the active count never exceeds `max_concurrent`, and
/// every submitted request eventually completes exactly once.
#[test]
fn fifo_admission_under_random_interleavings() {
    let reg = Registry::builtin().unwrap();
    let mut rng = XorShiftRng::new(0xF1F0);
    for trial in 0..5 {
        let max_concurrent = 1 + rng.below(3);
        let mut se = ServingEngine::new(
            &reg,
            ServeConfig { engine: EngineConfig::tiny_fused(), max_concurrent },
        )
        .unwrap();
        let mut submitted: Vec<u64> = Vec::new();
        for _ in 0..14 {
            if rng.below(2) == 0 {
                let id = se.submit(&[40 + rng.below(100)], 1 + rng.below(2)).unwrap();
                if let Some(&last) = submitted.last() {
                    assert!(id > last, "ids must be arrival-ordered");
                }
                submitted.push(id);
            } else {
                se.step_round().unwrap();
            }
            assert!(
                se.active.len() <= max_concurrent,
                "trial {trial}: active {} > cap {max_concurrent}",
                se.active.len()
            );
            // Admitted ids (active + finished) must be a FIFO prefix of
            // the arrival order.
            let mut admitted: Vec<u64> = se
                .active
                .iter()
                .chain(se.finished.iter())
                .map(|s| s.id)
                .collect();
            admitted.sort_unstable();
            assert_eq!(
                admitted,
                submitted[..admitted.len()].to_vec(),
                "trial {trial}: admission skipped the FIFO order"
            );
        }
        while se.step_round().unwrap() > 0 {}
        let done = se.drain_finished();
        let mut done_ids: Vec<u64> = done.iter().map(|s| s.id).collect();
        done_ids.sort_unstable();
        assert_eq!(done_ids, submitted, "trial {trial}: completion set mismatch");
        for s in &done {
            assert_eq!(s.tokens.len(), s.n_new, "trial {trial}: short generation");
        }
    }
}

/// The queue itself is FIFO under arbitrary push/pop interleavings.
#[test]
fn request_queue_is_fifo_for_random_op_sequences() {
    let mut rng = XorShiftRng::new(0x0F1F);
    for _ in 0..100 {
        let mut q = RequestQueue::new();
        let mut expected: std::collections::VecDeque<u64> = Default::default();
        for step in 0..40 {
            if rng.below(3) < 2 {
                let id = q.push(vec![rng.below(100)], 1 + rng.below(5), step as u64);
                expected.push_back(id);
            } else if let Some(r) = q.pop() {
                assert_eq!(Some(r.id), expected.pop_front(), "queue broke FIFO");
            } else {
                assert!(expected.is_empty());
            }
            assert_eq!(q.len(), expected.len());
        }
        assert_eq!(q.submitted as usize, q.len() + q.admitted as usize);
    }
}

// ------------------------------------------------------------ profiles ----
#[test]
fn profile_catalog_invariants() {
    let catalog = ImplementationProfile::table6_catalog();
    let mut names = std::collections::HashSet::new();
    for p in &catalog {
        assert!(names.insert(p.name), "duplicate profile {}", p.name);
        assert!(p.sequential_dispatch_ns() > 0);
        assert!(p.single_op_dispatch_ns() > 0);
        assert!(p.jitter_pct >= 0.0 && p.jitter_pct < 1.0);
        assert!(p.kernel_gflops > 0.0 && p.mem_gbps > 0.0);
        // Firefox floor only on firefox
        if p.implementation != "firefox" {
            assert_eq!(p.submit_floor_ns, 0, "{}", p.name);
        } else {
            assert!(p.submit_floor_ns > 1_000_000);
        }
    }
}

//! Property-based tests over coordinator invariants.
//!
//! proptest is unavailable in the offline build, so these use the in-tree
//! seeded generator (`XorShiftRng`) with wide randomized sweeps — same
//! spirit: each test states an invariant and hammers it with generated
//! cases; failures print the offending seed.

use wdb::fx::builder::{build_decode_graph, expected_dispatches, FusionConfig, GraphDims};
use wdb::fx::census::Census;
use wdb::fx::fusion;
use wdb::model::rng::XorShiftRng;
use wdb::report::json::{self, Value};
use wdb::stats::{summarize, t_critical_975, welch_t_test};
use wdb::stats::welch::t_p_value;
use wdb::tensor::Tensor;
use wdb::webgpu::clock::{Jitter, VirtualClock};
use wdb::webgpu::profile::PhaseCosts;
use wdb::webgpu::ImplementationProfile;

// ------------------------------------------------------------- census ----
#[test]
fn census_identities_hold_for_all_layer_counts() {
    for layers in 1..=96 {
        let dims = GraphDims {
            layers,
            ..GraphDims::qwen25_05b()
        };
        let c = Census::for_dims(&dims);
        // compute total follows 36L + 12
        assert_eq!(c.compute.total(), 36 * layers + 12, "L={layers}");
        // node total is the sum of its parts
        assert_eq!(
            c.total_nodes(),
            c.compute.total() + c.shape_ops + c.placeholders_outputs + c.metadata
        );
        // fused is strictly fewer and positive
        assert!(c.fused_dispatches() < c.unfused_dispatches());
        assert!(c.fused_dispatches() > 0);
        // savings = rmsnorm + mlp + kv = 13L
        assert_eq!(c.paper_fusion_savings().total(), 13 * layers);
    }
}

// ---------------------------------------------------------- fx builder ----
#[test]
fn decode_graphs_validate_for_random_architectures() {
    let mut rng = XorShiftRng::new(0xF00D);
    for trial in 0..40 {
        let head_dim = [8, 16, 32][rng.below(3)];
        let kv_heads = [1, 2, 4][rng.below(3)];
        let group = 1 + rng.below(4);
        let dims = GraphDims {
            hidden: kv_heads * group * head_dim,
            layers: 1 + rng.below(8),
            heads: kv_heads * group,
            kv_heads,
            head_dim,
            intermediate: 16 * (1 + rng.below(12)),
            vocab: 256 + 16 * rng.below(32),
            max_seq: 32,
            tiny_names: true,
        };
        for fusion_cfg in [
            FusionConfig::unfused(),
            FusionConfig::rmsnorm_only(),
            FusionConfig::rmsnorm_mlp(),
            FusionConfig::rmsnorm_mlp_kv(),
            FusionConfig::fused(),
        ] {
            let g = build_decode_graph(&dims, fusion_cfg);
            g.validate()
                .unwrap_or_else(|e| panic!("trial {trial} {dims:?} {fusion_cfg:?}: {e}"));
            assert_eq!(
                g.dispatch_count(),
                expected_dispatches(&dims, fusion_cfg),
                "trial {trial} {fusion_cfg:?}"
            );
        }
    }
}

#[test]
fn fusion_passes_preserve_ssa_and_reduce_dispatches() {
    let mut rng = XorShiftRng::new(0xFA57);
    for _ in 0..20 {
        let dims = GraphDims {
            layers: 1 + rng.below(6),
            ..GraphDims::qwen_tiny()
        };
        let g = build_decode_graph(&dims, FusionConfig::unfused());
        let f = fusion::fuse_all(&g, "tiny");
        f.validate().expect("fused graph must stay valid SSA");
        assert!(f.dispatch_count() < g.dispatch_count());
        // Passes reach exactly the builder's fully-fused count.
        let direct = build_decode_graph(&dims, FusionConfig::fused());
        assert_eq!(f.dispatch_count(), direct.dispatch_count());
        // Outputs preserved.
        assert_eq!(f.outputs.len(), g.outputs.len());
    }
}

// ------------------------------------------------------------- clock ----
#[test]
fn virtual_clock_is_monotone_under_random_ops() {
    let mut rng = XorShiftRng::new(0xC10C);
    for _ in 0..50 {
        let mut c = VirtualClock::new();
        let mut last_cpu = 0;
        for _ in 0..200 {
            match rng.below(3) {
                0 => c.advance_cpu(rng.below(10_000) as u64),
                1 => {
                    c.enqueue_gpu(rng.below(10_000) as u64);
                }
                _ => c.sync(rng.below(1_000) as u64),
            }
            assert!(c.cpu_ns >= last_cpu, "cpu clock went backwards");
            last_cpu = c.cpu_ns;
            assert!(c.gpu_busy_ns <= c.gpu_done_ns.max(c.cpu_ns) + c.gpu_busy_ns);
        }
        // After a final sync the CPU is at/past the GPU frontier.
        c.sync(0);
        assert!(c.cpu_ns >= c.gpu_done_ns);
    }
}

#[test]
fn jitter_stays_in_band_for_random_bases() {
    let mut rng = XorShiftRng::new(0x7177);
    let mut j = Jitter::new(0x1234);
    for _ in 0..500 {
        let base = rng.below(1_000_000) as u64;
        let pct = rng.uniform() * 0.5;
        let v = j.apply(base, pct);
        let lo = (base as f64 * (1.0 - pct) - 1.0).max(0.0);
        let hi = base as f64 * (1.0 + pct) + 1.0;
        assert!(
            (v as f64) >= lo && (v as f64) <= hi,
            "jitter {v} outside [{lo}, {hi}] for base {base} pct {pct}"
        );
    }
}

#[test]
fn phase_costs_preserve_total_for_random_values() {
    let mut rng = XorShiftRng::new(0xFACE);
    for _ in 0..500 {
        let total = rng.below(10_000_000) as u64;
        let pc = PhaseCosts::from_total(total);
        assert_eq!(pc.total(), total, "total {total}");
    }
}

// -------------------------------------------------------------- stats ----
#[test]
fn ci_contains_mean_for_random_samples() {
    let mut rng = XorShiftRng::new(0x57A7);
    for _ in 0..100 {
        let n = 2 + rng.below(50);
        let mu = rng.uniform_in(-100.0, 100.0);
        let sigma = rng.uniform_in(0.01, 10.0);
        let xs: Vec<f64> = (0..n).map(|_| mu + sigma * rng.normal()).collect();
        let s = summarize(&xs);
        assert!(s.ci95_lo <= s.mean && s.mean <= s.ci95_hi);
        assert!(s.std >= 0.0);
    }
}

#[test]
fn welch_p_is_symmetric_and_bounded() {
    let mut rng = XorShiftRng::new(0x3E1C);
    for _ in 0..100 {
        let na = 3 + rng.below(20);
        let nb = 3 + rng.below(20);
        let a: Vec<f64> = (0..na).map(|_| rng.normal() * 2.0 + 1.0).collect();
        let b: Vec<f64> = (0..nb).map(|_| rng.normal() * 3.0 - 1.0).collect();
        let ab = welch_t_test(&a, &b);
        let ba = welch_t_test(&b, &a);
        assert!((0.0..=1.0).contains(&ab.p), "p {}", ab.p);
        assert!((ab.p - ba.p).abs() < 1e-9, "asymmetric p");
        assert!((ab.t + ba.t).abs() < 1e-9, "t not antisymmetric");
    }
}

#[test]
fn t_p_value_monotone_in_t() {
    for df in [2.0, 5.0, 10.0, 29.0, 100.0] {
        let mut last = 1.0 + 1e-12;
        for i in 0..60 {
            let t = i as f64 * 0.25;
            let p = t_p_value(t, df);
            assert!(p <= last + 1e-12, "p not decreasing at t={t}, df={df}");
            last = p;
        }
    }
}

#[test]
fn t_critical_monotone_decreasing_in_df() {
    let mut last = f64::INFINITY;
    for df in 1..200 {
        let t = t_critical_975(df as f64);
        assert!(t <= last + 1e-9, "t_crit not decreasing at df={df}");
        assert!(t >= 1.9);
        last = t;
    }
}

// --------------------------------------------------------------- json ----
fn random_json(rng: &mut XorShiftRng, depth: usize) -> Value {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Value::Null,
        1 => Value::Bool(rng.below(2) == 0),
        2 => Value::Num((rng.uniform_in(-1e6, 1e6) * 100.0).round() / 100.0),
        3 => {
            let len = rng.below(12);
            let s: String = (0..len)
                .map(|_| {
                    let c = rng.below(96) as u8 + 32;
                    c as char
                })
                .collect();
            Value::Str(s)
        }
        4 => Value::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => {
            let mut m = std::collections::BTreeMap::new();
            for i in 0..rng.below(5) {
                m.insert(format!("k{i}"), random_json(rng, depth - 1));
            }
            Value::Obj(m)
        }
    }
}

#[test]
fn json_roundtrips_random_documents() {
    let mut rng = XorShiftRng::new(0x1507);
    for trial in 0..200 {
        let v = random_json(&mut rng, 3);
        let compact = json::to_string(&v);
        let pretty = json::to_string_pretty(&v);
        assert_eq!(json::parse(&compact).unwrap(), v, "trial {trial}: {compact}");
        assert_eq!(json::parse(&pretty).unwrap(), v, "trial {trial}");
    }
}

// ------------------------------------------------------------- tensor ----
#[test]
fn tensor_slice_concat_identity() {
    let mut rng = XorShiftRng::new(0x7E50);
    for _ in 0..100 {
        let rows = 1 + rng.below(6);
        let cols = 2 * (1 + rng.below(16));
        let data = rng.normal_vec_f32(rows * cols, 1.0);
        let t = Tensor::f32(vec![rows, cols], data.clone()).unwrap();
        let a = t.slice_last_2d(0, cols / 2).unwrap();
        let b = t.slice_last_2d(cols / 2, cols).unwrap();
        // splicing halves back reproduces the rows
        for r in 0..rows {
            let row: Vec<f32> = a.as_f32().unwrap()[r * cols / 2..(r + 1) * cols / 2]
                .iter()
                .chain(&b.as_f32().unwrap()[r * cols / 2..(r + 1) * cols / 2])
                .copied()
                .collect();
            assert_eq!(&row, &data[r * cols..(r + 1) * cols]);
        }
    }
}

#[test]
fn tensor_argmax_agrees_with_scan() {
    let mut rng = XorShiftRng::new(0xA93A);
    for _ in 0..100 {
        let n = 1 + rng.below(2000);
        let data = rng.normal_vec_f32(n, 5.0);
        let t = Tensor::f32(vec![1, n], data.clone()).unwrap();
        let got = t.argmax_row().unwrap();
        let want = data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))
            .unwrap()
            .0;
        assert_eq!(got, want);
    }
}

// ------------------------------------------------------------ profiles ----
#[test]
fn profile_catalog_invariants() {
    let catalog = ImplementationProfile::table6_catalog();
    let mut names = std::collections::HashSet::new();
    for p in &catalog {
        assert!(names.insert(p.name), "duplicate profile {}", p.name);
        assert!(p.sequential_dispatch_ns() > 0);
        assert!(p.single_op_dispatch_ns() > 0);
        assert!(p.jitter_pct >= 0.0 && p.jitter_pct < 1.0);
        assert!(p.kernel_gflops > 0.0 && p.mem_gbps > 0.0);
        // Firefox floor only on firefox
        if p.implementation != "firefox" {
            assert_eq!(p.submit_floor_ns, 0, "{}", p.name);
        } else {
            assert!(p.submit_floor_ns > 1_000_000);
        }
    }
}

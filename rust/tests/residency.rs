//! Device-resident KV cache integration tests: per-session cache
//! isolation, reset-then-reuse at the capacity boundary, leak detection
//! through the bounded pool's high-water stats, the evict-to-host spill
//! path, and the upload-bytes acceptance bar (>= 10x shrink vs eager).

use wdb::engine::{Engine, EngineConfig, ExecMode};
use wdb::model::ByteTokenizer;
use wdb::runtime::Registry;
use wdb::serve::{ServeConfig, ServingEngine, SessionState};

const SEED: u64 = 0x6E51;

fn registry() -> Registry {
    Registry::builtin().expect("builtin registry")
}

fn serving(reg: &Registry, exec: ExecMode, max_concurrent: usize) -> ServingEngine<'_> {
    // This suite pins the PR 3 CONTIGUOUS residency contract (per-session
    // DeviceKvCache sets, whole-set evict/hydrate, cache-set pool
    // accounting); the paged block-table layout has its own suite in
    // `tests/paged.rs`.
    let cfg = EngineConfig { exec, paged: false, ..EngineConfig::tiny_fused() };
    let mut se = ServingEngine::new(reg, ServeConfig { engine: cfg, max_concurrent })
        .expect("serving engine");
    se.reseed(SEED);
    se
}

/// One encode+finish step of a detached session through the public API.
fn step_once(se: &mut ServingEngine, s: &mut SessionState) {
    let (tok, was_prompt) = s.take_input().expect("input token");
    let h = se.encode_session(s, tok, was_prompt).expect("encode");
    se.finish_session(s, h).expect("finish");
}

/// Drive one detached session to completion through the public
/// encode/finish API.
fn drive(se: &mut ServingEngine, s: &mut SessionState) -> Vec<usize> {
    while !s.finished() {
        step_once(se, s);
    }
    s.tokens.clone()
}

/// Acceptance: with resident caches, per-step host upload bytes drop from
/// O(layers x max_seq x kv_heads x head_dim) to the token embedding +
/// position uniforms — at least 10x on the default decode workload — and
/// the measured per-step traffic matches the plan's static accounting.
#[test]
fn resident_caches_shrink_upload_bytes_at_least_10x() {
    let reg = registry();
    let prompt = ByteTokenizer::new(512).paper_prompt();
    let tokens = 6;
    let run = |exec: ExecMode| {
        // Token-by-token prompt ingestion: this test pins the per-STEP
        // upload accounting against the decode plan's static StepInput
        // bytes, which chunked prefill (its own suite: tests/prefill.rs)
        // deliberately changes during the prompt phase.
        let cfg =
            EngineConfig { exec, prefill_chunk: 0, paged: false, ..EngineConfig::tiny_fused() };
        let mut se = ServingEngine::new(&reg, ServeConfig { engine: cfg, max_concurrent: 1 })
            .expect("serving engine");
        se.reseed(SEED);
        se.submit(&prompt, tokens).unwrap();
        let report = se.run_to_completion().unwrap();
        (report, se)
    };
    let (eager, _) = run(ExecMode::Eager);
    let (planned, se) = run(ExecMode::Planned);
    assert_eq!(eager.total_tokens, planned.total_tokens);
    let e = eager.upload_bytes_per_step();
    let p = planned.upload_bytes_per_step();
    assert!(
        p * 10.0 <= e,
        "upload bytes/step must shrink >= 10x: eager {e:.0} vs planned {p:.0}"
    );
    // The measured planned traffic is exactly the plan's StepInput bytes
    // (token embedding + 3 position uniforms + rope frequencies).
    let plan = se.executor.plan().expect("planned engine has a plan");
    assert_eq!(p, plan.stats.upload_bytes_per_step as f64);
    assert!(plan.stats.persistent_values > 0);
    assert_eq!(planned.resident_bytes, plan.stats.resident_bytes as u64);
    // Eager still pays the cache round-trip: it uploads at least the full
    // cache set every step.
    assert!(e >= plan.stats.resident_bytes as f64);
}

/// Cross-session isolation: two live sessions own disjoint cache buffers,
/// and stepping one session leaves the other's cache bytes bit-identical.
#[test]
fn session_cache_updates_never_touch_other_sessions_buffers() {
    let reg = registry();
    let mut se = serving(&reg, ExecMode::Planned, 2);
    let mut a = se.create_session(vec![65, 66, 67], 6, 1);
    let mut b = se.create_session(vec![90, 91], 6, 2);

    step_once(&mut se, &mut a);
    step_once(&mut se, &mut b);

    let bufs_a = a.kv.as_device().expect("A promoted to device").buffers.clone();
    let bufs_b = b.kv.as_device().expect("B promoted to device").buffers.clone();
    assert!(
        bufs_a.iter().all(|x| !bufs_b.contains(x)),
        "live sessions must own disjoint cache buffers"
    );

    // Snapshot A's cache bytes, then advance only B.
    let snap: Vec<Vec<u8>> = bufs_a
        .iter()
        .map(|&buf| se.executor.device.peek_buffer(buf).unwrap().to_vec())
        .collect();
    step_once(&mut se, &mut b);
    step_once(&mut se, &mut b);
    for (i, &buf) in bufs_a.iter().enumerate() {
        assert_eq!(
            se.executor.device.peek_buffer(buf).unwrap(),
            snap[i].as_slice(),
            "B's cache_update dispatches wrote into A's buffer {i}"
        );
    }
    // And A still decodes correctly afterwards.
    let ta = drive(&mut se, &mut a);
    let mut solo = serving(&reg, ExecMode::Planned, 1);
    let mut fresh = solo.create_session(vec![65, 66, 67], 6, 9);
    assert_eq!(ta, drive(&mut solo, &mut fresh), "A corrupted by B's steps");
}

/// Reset-then-reuse at the max_seq boundary: fill a session's cache to
/// capacity, confirm the capacity guard fires, reset (device buffers
/// released + zeroed on realloc), and decode the same stream again.
#[test]
fn reset_then_reuse_at_max_seq_boundary() {
    let reg = registry();
    let dims = wdb::fx::builder::GraphDims::qwen_tiny();
    let mut se = serving(&reg, ExecMode::Planned, 1);
    let prompt = vec![65usize, 66];
    let n_new = dims.max_seq - prompt.len() + 1; // steps == max_seq exactly
    let mut s = se.create_session(prompt.clone(), n_new, 1);
    let first = drive(&mut se, &mut s);
    assert_eq!(s.pos, dims.max_seq, "cache filled to the boundary");

    // One more step must hit the capacity guard, not corrupt memory.
    let err = se.encode_session(&mut s, 5, false);
    assert!(err.is_err(), "encode past max_seq must error");

    // Full reset: host state rewound AND device cache released.
    se.reset_session(&mut s).unwrap();
    assert_eq!(s.pos, 0);
    assert!(s.tokens.is_empty());
    assert!(!s.kv.is_device(), "reset must release the device cache set");

    let again = drive(&mut se, &mut s);
    assert_eq!(again, first, "reset session must reproduce the stream");
}

/// Leak detection: cache sets return to the pool on retire, so repeated
/// session batches keep the pool's created-buffer count and high-water
/// bytes flat, outstanding bytes at zero, and the arena's live-set count
/// balanced.
#[test]
fn retired_cache_sets_recycle_with_flat_high_water() {
    let reg = registry();
    let mut se = serving(&reg, ExecMode::Planned, 2);
    se.submit(&[65, 66], 4).unwrap();
    se.submit(&[70, 71], 4).unwrap();
    se.run_to_completion().unwrap();
    let ps1 = se.executor.pool.stats();
    assert_eq!(ps1.outstanding_bytes, 0, "retire must release cache sets");
    assert!(ps1.created > 0);

    for batch in 0..3 {
        se.submit(&[80 + batch, 81], 4).unwrap();
        se.submit(&[85, 86 + batch], 4).unwrap();
        se.run_to_completion().unwrap();
    }
    let ps2 = se.executor.pool.stats();
    assert_eq!(
        ps2.created, ps1.created,
        "later batches must recycle cache buffers, not create"
    );
    assert_eq!(
        ps2.high_water_bytes, ps1.high_water_bytes,
        "cache-set high water must stay flat across batches (leak!)"
    );
    assert_eq!(ps2.outstanding_bytes, 0);
    let arena = se.executor.kv_arena().expect("planned engine has a cache arena");
    assert_eq!(arena.stats().sets_live(), 0, "every allocated set released");
    assert_eq!(se.executor.device.stats.validation_errors, 0);
    assert_eq!(se.drain_finished().len(), 8);
}

/// Steady-state session churn is fully allocation-free: after the first
/// batch warms the pool and the per-cache-set bind groups, further batches
/// create zero device buffers and zero bind groups.
#[test]
fn session_churn_creates_no_resources_after_warmup() {
    let reg = registry();
    let mut se = serving(&reg, ExecMode::Planned, 2);
    se.submit(&[65], 3).unwrap();
    se.submit(&[66], 3).unwrap();
    se.run_to_completion().unwrap();
    let bufs0 = se.executor.device.stats.buffers_created;
    let groups0 = se.executor.device.stats.bind_groups_created;
    se.submit(&[67], 3).unwrap();
    se.submit(&[68], 3).unwrap();
    se.run_to_completion().unwrap();
    assert_eq!(se.executor.device.stats.buffers_created, bufs0, "buffers leaked");
    assert_eq!(
        se.executor.device.stats.bind_groups_created, groups0,
        "recycled cache sets must hit the bind-group cache"
    );
    // The per-cache-set group map is bounded by the distinct buffer
    // orderings, which reverse-order release keeps at the concurrency cap.
    let runner = se.executor.plan_runner().expect("planned");
    assert_eq!(runner.registered_cache_sets(), 2, "group map grew under churn");
}

/// Cache-aware admission: when the bounded pool can back only one resident
/// cache set, excess requests stay queued (deferred to the retiring
/// session's recycled set) instead of poisoning the run mid-encode; a cap
/// too small for even one set surfaces the error instead of spinning.
#[test]
fn cache_pressure_defers_admission_instead_of_failing() {
    let reg = registry();
    let dims = wdb::fx::builder::GraphDims::qwen_tiny();
    let set_bytes = 2 * dims.layers * dims.max_seq * dims.kv_heads * dims.head_dim * 4;

    // Contiguous admission semantics (paged admission never rejects — it
    // pages instead; that contract is pinned in `tests/paged.rs`).
    let mut cfg =
        EngineConfig { exec: ExecMode::Planned, paged: false, ..EngineConfig::tiny_fused() };
    cfg.pool_cap_bytes = Some(set_bytes); // exactly ONE session's set
    let mut se =
        ServingEngine::new(&reg, ServeConfig { engine: cfg, max_concurrent: 2 }).unwrap();
    se.reseed(SEED);
    let ida = se.submit(&[65, 66], 3).unwrap();
    let idb = se.submit(&[70, 71], 3).unwrap();
    let report = se.run_to_completion().expect("pressure must defer, not fail");
    assert_eq!(report.sessions, 2, "both requests complete");
    let done = se.drain_finished();
    assert_eq!(done[0].id, ida, "FIFO under deferred admission");
    assert_eq!(done[1].id, idb);
    assert_eq!(
        se.executor.pool.stats().total_bytes,
        set_bytes,
        "second session must run on the retired session's recycled set"
    );

    // Below one set, the very first admission must error (not spin).
    let mut tiny =
        EngineConfig { exec: ExecMode::Planned, paged: false, ..EngineConfig::tiny_fused() };
    tiny.pool_cap_bytes = Some(set_bytes - 1);
    let mut se2 =
        ServingEngine::new(&reg, ServeConfig { engine: tiny, max_concurrent: 1 }).unwrap();
    se2.submit(&[65], 2).unwrap();
    assert!(se2.run_to_completion().is_err(), "sub-set cap must surface");
}

/// Evict-to-host spill path: a session parked mid-generation releases its
/// device buffers, keeps its context host-side, and resumes bit-identically
/// after transparent re-hydration.
#[test]
fn evict_mid_generation_resumes_bit_identically() {
    let reg = registry();
    let prompt = vec![72usize, 101, 108];
    let tokens = 7;

    let mut truth_se = serving(&reg, ExecMode::Planned, 1);
    let mut truth = truth_se.create_session(prompt.clone(), tokens, 1);
    let expect = drive(&mut truth_se, &mut truth);

    let mut se = serving(&reg, ExecMode::Planned, 1);
    let mut s = se.create_session(prompt.clone(), tokens, 2);
    for _ in 0..3 {
        let (tok, was_prompt) = s.take_input().unwrap();
        let h = se.encode_session(&mut s, tok, was_prompt).unwrap();
        se.finish_session(&mut s, h).unwrap();
    }
    let outstanding_before = se.executor.pool.stats().outstanding_bytes;
    se.evict_session_cache(&mut s).unwrap();
    assert!(!s.kv.is_device(), "evicted session is host-resident");
    assert!(
        se.executor.pool.stats().outstanding_bytes < outstanding_before,
        "evict must return the cache set to the pool"
    );
    let host = s.kv.as_host().expect("spilled caches");
    assert_eq!(host.len(), wdb::fx::builder::GraphDims::qwen_tiny().layers);
    assert!(
        host.iter().any(|(k, _)| k.as_f32().unwrap().iter().any(|&x| x != 0.0)),
        "spilled cache must carry the session's context"
    );

    let got = drive(&mut se, &mut s);
    assert_eq!(got, expect, "evict/re-hydrate changed the token stream");
}

/// Engine::generate recycles its session's cache set between runs (no
/// leak across generates) and Engine::reset releases it explicitly.
#[test]
fn engine_generate_and_reset_recycle_cache_sets() {
    let reg = registry();
    let mut e = Engine::new(&reg, EngineConfig::tiny_planned()).unwrap();
    let _ = e.generate(&[65, 66], 3).unwrap();
    let created0 = e.executor.device.stats.buffers_created;
    for _ in 0..3 {
        let _ = e.generate(&[65, 66], 3).unwrap();
    }
    assert_eq!(
        e.executor.device.stats.buffers_created, created0,
        "back-to-back generates must recycle the cache set"
    );
    e.reset().unwrap();
    assert_eq!(e.executor.pool.stats().outstanding_bytes, 0, "reset releases caches");
    let arena = e.executor.kv_arena().unwrap();
    assert_eq!(arena.stats().sets_live(), 0);
}

/// The serving default is planned replay with resident caches; eager stays
/// available and bit-identical (the paper's pathology remains runnable).
#[test]
fn serving_default_is_planned_and_eager_stays_equivalent() {
    assert_eq!(ExecMode::serving_default(), ExecMode::Planned);
    let reg = registry();
    let cfg = EngineConfig::tiny_serving();
    assert_eq!(cfg.exec, ExecMode::Planned);
    assert!(cfg.paged, "paged KV residency is the planned serving default");
    let prompt = ByteTokenizer::new(512).paper_prompt();
    let run = |exec: ExecMode| {
        let mut se = serving(&reg, exec, 2);
        se.submit(&prompt, 5).unwrap();
        se.submit(&prompt, 5).unwrap();
        let report = se.run_to_completion().unwrap();
        let toks: Vec<Vec<usize>> = se.drain_finished().into_iter().map(|s| s.tokens).collect();
        (toks, report)
    };
    let (eager_toks, eager_rep) = run(ExecMode::Eager);
    let (planned_toks, planned_rep) = run(ExecMode::Planned);
    assert_eq!(eager_toks, planned_toks, "modes must stay bit-identical");
    assert_eq!(eager_rep.exec_mode(), "eager");
    assert_eq!(planned_rep.exec_mode(), "planned");
    assert!(planned_rep.planned);
    assert!(planned_rep.resident_bytes > 0);
    assert_eq!(eager_rep.resident_bytes, 0);
}

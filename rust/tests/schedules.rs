//! Randomized differential serving-schedule suite — the unified-round
//! correctness acceptance gate.
//!
//! A seeded generator produces serving schedules (staggered Poisson-ish
//! arrivals, prompt lengths spanning the chunking equivalence classes
//! {1, C-1, C, C+1, 3C+5, 128}, varied generation lengths so sessions
//! retire mid-run, and more requests than `max_concurrent` so admission
//! churns slots). Every schedule runs through FOUR scheduling modes over
//! the same weights:
//!
//!   - **unified**      — the serving default: every round replays the
//!                        seq-x-batch `[W*C, H]` graph (mixed
//!                        prefill/decode rounds, one dispatch per layer
//!                        op per chunk of slots);
//!   - **speculative**  — unified plus `speculate: 3`: decode slots carry
//!                        up to 3 n-gram-drafted tokens per round, scored
//!                        by the multi-row verify tail and greedily
//!                        accepted/rewound on the host — a scheduling
//!                        change only, never a sampling change;
//!   - **split**        — `unified: false`: PR-4/PR-5 scheduling (chunked
//!                        prefill rounds, then batched decode rounds);
//!   - **interleaved**  — `batch_width: 0, prefill_chunk: 0`: per-session
//!                        planned replays, token-by-token prompts;
//!   - **fault**        — unified plus a schedule-derived seeded transient
//!                        fault plan (dispatch failures, allocation
//!                        failures, map-read timeouts injected at the
//!                        device layer): quarantine + snapshot-replay
//!                        recovery must absorb every fault without moving
//!                        a single token or KV byte;
//!   - **contiguous**   — unified with `paged: false`: the paged KV
//!                        layout (the planned default in every arm above:
//!                        block tables + shared block pool + per-block
//!                        LRU pager) swapped back for PR 3 per-session
//!                        contiguous cache sets — the block-table
//!                        indirection is a pure layout change, so token
//!                        streams AND spilled-KV bytes must match
//!                        byte-for-byte.
//!
//! The suite asserts BYTE-level equivalence: identical token streams for
//! every request, and identical spilled-KV-cache bytes for a probe
//! session evicted mid-run right after its first generated token (the
//! same per-session state point in all four modes, however many rounds
//! each mode took to reach it — the probe fires at the final prefill
//! chunk, before any speculative round touches the session, so rejected
//! drafts' dead KV rows can never enter the comparison). A failure prints
//! the offending seed.
//!
//! The suite doubles as the tracer's acceptance gate: the speculative,
//! split, and fault arms run with a live ring-sink tracer (the unified
//! reference keeps the Null sink), so the token/KV identity asserts also
//! prove tracing never perturbs the schedule, and every traced arm's
//! retained stream must hold balanced LIFO span stacks on every track
//! with zero ring drops — including across fault quarantine/replay and
//! speculative rewind paths.
//!
//! Seeds are split across several #[test] fns so the default test
//! harness runs them in parallel.

use wdb::engine::{EngineConfig, ExecMode};
use wdb::fx::builder::FusionConfig;
use wdb::model::rng::XorShiftRng;
use wdb::runtime::Registry;
use wdb::serve::{ServeConfig, ServingEngine};

/// Virtual-cost jitter seed — identical across modes so virtual-time
/// bookkeeping differences can never masquerade as scheduling effects.
const RESEED: u64 = 0x5C4ED;
/// The default prefill chunk the length classes are derived from.
const CHUNK: usize = 16;
/// qwen-tiny KV capacity: prompt + generated - 1 must fit.
const MAX_SEQ: usize = 160;

fn registry() -> Registry {
    Registry::builtin().expect("builtin registry")
}

struct Req {
    prompt: Vec<usize>,
    gen: usize,
    /// Scheduler-loop iteration at which the request is submitted.
    arrival: usize,
}

struct Schedule {
    max_concurrent: usize,
    /// Request index whose KV cache is spilled and compared mid-run.
    target: usize,
    reqs: Vec<Req>,
}

/// Deterministic schedule for one seed. Always oversubscribed (more
/// requests than `max_concurrent`), always at least one mid-run arrival
/// candidate, every generation length >= 2 so the KV probe target is
/// still active right after its first token.
fn gen_schedule(seed: u64) -> Schedule {
    let mut rng = XorShiftRng::new(0xD1FF ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let max_concurrent = 2 + rng.below(4); // 2..=5 slots
    let n_reqs = max_concurrent + 1 + rng.below(4); // strictly > max_concurrent
    let lens = [1usize, CHUNK - 1, CHUNK, CHUNK + 1, 3 * CHUNK + 5];
    let reqs = (0..n_reqs)
        .map(|i| {
            // The 128-token long-prompt class is sampled sparingly: it
            // dominates debug-profile wall time without adding new
            // equivalence classes beyond 3C+5.
            let plen = if rng.below(8) == 0 { 128 } else { lens[rng.below(lens.len())] };
            let prompt: Vec<usize> =
                (0..plen).map(|t| 7 + (t * 13 + i * 31 + seed as usize) % 500).collect();
            let gen = 2 + rng.below(6); // 2..=7
            assert!(plen + gen - 1 <= MAX_SEQ);
            let arrival = if rng.below(2) == 0 { 0 } else { 1 + rng.below(8) };
            Req { prompt, gen, arrival }
        })
        .collect::<Vec<_>>();
    let target = rng.below(n_reqs);
    Schedule { max_concurrent, target, reqs }
}

fn unified_cfg() -> EngineConfig {
    EngineConfig {
        fusion: FusionConfig::fused(),
        exec: ExecMode::Planned,
        ..EngineConfig::tiny_fused()
    }
}

fn spec_cfg() -> EngineConfig {
    EngineConfig { speculate: 3, ..unified_cfg() }
}

fn split_cfg() -> EngineConfig {
    EngineConfig { unified: false, ..unified_cfg() }
}

fn interleaved_cfg() -> EngineConfig {
    EngineConfig { batch_width: 0, prefill_chunk: 0, ..unified_cfg() }
}

/// The paged layout swapped back for PR 3 contiguous cache sets: the
/// `--no-paged` differential arm.
fn contiguous_cfg() -> EngineConfig {
    EngineConfig { paged: false, ..unified_cfg() }
}

/// Unified scheduling under a seeded transient-fault plan derived from the
/// schedule seed (so every schedule exercises a different fault mix).
fn fault_cfg(seed: u64) -> EngineConfig {
    EngineConfig {
        fault_seed: Some(0xFA_17 ^ seed.wrapping_mul(0x9E37_79B9)),
        ..unified_cfg()
    }
}

/// Arm with a live ring-sink tracer: large enough that no schedule in the
/// suite ever wraps it, so `run_schedule` can demand zero drops plus a
/// balanced span stack over the full retained stream.
fn traced(mut cfg: EngineConfig) -> EngineConfig {
    cfg.trace = wdb::trace::TraceConfig {
        sink: wdb::trace::TraceSinkKind::Ring,
        ring: 1 << 20,
    };
    cfg
}

/// Drive one engine through the schedule: submit each request at its
/// arrival iteration, step rounds until everything drains, and spill the
/// probe session's KV cache the first round it holds a generated token
/// (it re-hydrates next round — the resume path is part of the suite).
/// Returns (per-request token streams, probe KV bytes per layer tensor).
fn run_schedule(
    reg: &Registry,
    cfg: EngineConfig,
    sched: &Schedule,
) -> (Vec<Vec<usize>>, Vec<Vec<u8>>) {
    let mut se = ServingEngine::new(
        reg,
        ServeConfig { engine: cfg, max_concurrent: sched.max_concurrent },
    )
    .expect("serving engine");
    se.reseed(RESEED);
    let mut ids: Vec<Option<u64>> = vec![None; sched.reqs.len()];
    let mut kv: Vec<Vec<u8>> = Vec::new();
    let mut it = 0usize;
    loop {
        for (i, rq) in sched.reqs.iter().enumerate() {
            if rq.arrival == it {
                ids[i] = Some(se.submit(&rq.prompt, rq.gen).expect("submit"));
            }
        }
        let pending = sched.reqs.iter().any(|rq| rq.arrival > it);
        if se.active.is_empty() && se.queue.is_empty() {
            if !pending {
                break;
            }
            it += 1;
            continue;
        }
        se.step_round().expect("step_round");
        // KV probe: the first round after which the target session has
        // recorded a generated token, its cache holds exactly
        // prompt.len() rows in EVERY mode (per-session progress is
        // measured in its own steps, not rounds) — spill and snapshot.
        if kv.is_empty() {
            if let Some(tid) = ids[sched.target] {
                if let Some(pos) =
                    se.active.iter().position(|s| s.id == tid && !s.tokens.is_empty())
                {
                    let mut s = se.active.remove(pos);
                    assert_eq!(s.pos, s.prompt.len(), "probe point must be post-prefill");
                    se.evict_session_cache(&mut s).expect("evict");
                    for (k, v) in s.kv.as_host().expect("spilled") {
                        kv.push(k.data.as_bytes().to_vec());
                        kv.push(v.data.as_bytes().to_vec());
                    }
                    se.active.insert(pos, s);
                }
            }
        }
        it += 1;
        assert!(it < 10_000, "schedule failed to drain");
    }
    // Tracer invariants for arms running an event-retaining sink: the
    // ring never wrapped (so the stream below is complete) and every
    // track's Begin/End pairs are balanced and LIFO-nested — fault
    // quarantine, retries, and speculative rewinds included.
    if se.tracer().on() {
        assert_eq!(
            se.tracer().dropped_events(),
            0,
            "trace ring overflowed mid-suite; raise the test ring capacity"
        );
        if let Err(e) = wdb::trace::validate_balance(&se.tracer().drain()) {
            panic!("trace span-stack invariant violated: {e}");
        }
    }
    let done = se.drain_finished();
    let toks = ids
        .iter()
        .map(|id| {
            let id = id.expect("all requests submitted");
            done.iter().find(|s| s.id == id).expect("finished").tokens.clone()
        })
        .collect();
    (toks, kv)
}

/// The differential core: three modes, one schedule, byte identity.
fn differential(reg: &Registry, seeds: std::ops::Range<u64>) {
    for seed in seeds {
        let sched = gen_schedule(seed);
        let ctx = format!(
            "seed {seed} (max_concurrent={}, requests={}, target={})",
            sched.max_concurrent,
            sched.reqs.len(),
            sched.target
        );
        let (u_toks, u_kv) = run_schedule(reg, unified_cfg(), &sched);
        // Speculative, split, and fault arms carry a live ring tracer:
        // the identity asserts below then also pin sink-independence
        // (tracing on vs the unified arm's Null sink moves nothing).
        let (p_toks, p_kv) = run_schedule(reg, traced(spec_cfg()), &sched);
        let (s_toks, s_kv) = run_schedule(reg, traced(split_cfg()), &sched);
        let (i_toks, i_kv) = run_schedule(reg, interleaved_cfg(), &sched);
        let (f_toks, f_kv) = run_schedule(reg, traced(fault_cfg(seed)), &sched);
        let (c_toks, c_kv) = run_schedule(reg, contiguous_cfg(), &sched);
        assert_eq!(u_toks, p_toks, "{ctx}: unified vs speculative token streams diverged");
        assert_eq!(u_toks, s_toks, "{ctx}: unified vs split token streams diverged");
        assert_eq!(u_toks, i_toks, "{ctx}: unified vs interleaved token streams diverged");
        assert_eq!(u_toks, f_toks, "{ctx}: unified vs fault-injected token streams diverged");
        assert_eq!(u_toks, c_toks, "{ctx}: paged vs contiguous token streams diverged");
        // The probe session generated at least one token in every mode,
        // so the spill always captured a snapshot.
        assert!(!u_kv.is_empty(), "{ctx}: probe never fired");
        assert_eq!(u_kv, p_kv, "{ctx}: unified vs speculative spilled-KV bytes diverged");
        assert_eq!(u_kv, s_kv, "{ctx}: unified vs split spilled-KV bytes diverged");
        assert_eq!(u_kv, i_kv, "{ctx}: unified vs interleaved spilled-KV bytes diverged");
        assert_eq!(u_kv, f_kv, "{ctx}: unified vs fault-injected spilled-KV bytes diverged");
        assert_eq!(u_kv, c_kv, "{ctx}: paged vs contiguous spilled-KV bytes diverged");
    }
}

#[test]
fn schedule_seeds_00_09() {
    differential(&registry(), 0..10);
}

#[test]
fn schedule_seeds_10_19() {
    differential(&registry(), 10..20);
}

#[test]
fn schedule_seeds_20_29() {
    differential(&registry(), 20..30);
}

#[test]
fn schedule_seeds_30_39() {
    differential(&registry(), 30..40);
}

#[test]
fn schedule_seeds_40_49() {
    differential(&registry(), 40..50);
}

/// Oversubscription past the kernel batch width: 6 concurrent slots over
/// width-4 unified replays (two chunk-of-slots per round) with 8 staggered
/// requests, still byte-identical across all three modes.
#[test]
fn oversubscribed_wide_rounds_match_across_modes() {
    let reg = registry();
    let lens = [1usize, 15, 16, 17, 53, 5, 33, 2];
    let sched = Schedule {
        max_concurrent: 6,
        target: 4,
        reqs: lens
            .iter()
            .enumerate()
            .map(|(i, &plen)| Req {
                prompt: (0..plen).map(|t| 11 + (t * 17 + i * 41) % 480).collect(),
                gen: 2 + (i * 5) % 6,
                arrival: (i / 3) * 2, // arrivals in waves: 0, 0, 0, 2, 2, 2, 4, 4
            })
            .collect(),
    };
    let (u_toks, u_kv) = run_schedule(&reg, unified_cfg(), &sched);
    let (p_toks, p_kv) = run_schedule(&reg, spec_cfg(), &sched);
    let (s_toks, s_kv) = run_schedule(&reg, split_cfg(), &sched);
    let (i_toks, i_kv) = run_schedule(&reg, interleaved_cfg(), &sched);
    assert_eq!(u_toks, p_toks, "wide rounds: unified vs speculative diverged");
    assert_eq!(u_toks, s_toks, "wide rounds: unified vs split diverged");
    assert_eq!(u_toks, i_toks, "wide rounds: unified vs interleaved diverged");
    assert_eq!(u_kv, p_kv, "wide rounds: spilled-KV bytes diverged (speculative)");
    assert_eq!(u_kv, s_kv, "wide rounds: spilled-KV bytes diverged (split)");
    assert_eq!(u_kv, i_kv, "wide rounds: spilled-KV bytes diverged (interleaved)");
}

/// Speculation and fault injection composed: a quarantined session stops
/// drafting while degraded, yet token streams and spilled-KV bytes must
/// still match the clean unified run. A seed subset keeps this cheap —
/// each feature already takes the full 50-seed sweep on its own.
#[test]
fn speculative_fault_schedules_match_clean_unified() {
    let reg = registry();
    for seed in 0..8u64 {
        let sched = gen_schedule(seed);
        let (u_toks, u_kv) = run_schedule(&reg, unified_cfg(), &sched);
        let cfg = traced(EngineConfig { speculate: 3, ..fault_cfg(seed) });
        let (f_toks, f_kv) = run_schedule(&reg, cfg, &sched);
        assert_eq!(u_toks, f_toks, "seed {seed}: spec+faults token streams diverged");
        assert_eq!(u_kv, f_kv, "seed {seed}: spec+faults spilled-KV bytes diverged");
    }
}

/// The smallest block size (4 tokens) maximizes block-boundary crossings
/// per schedule — every prompt length class straddles several blocks and
/// each decode step lands a new tail block far more often than the
/// default 16-token layout. A seed subset must stay byte-identical to the
/// default-block unified run (block size is a layout knob, not a
/// numerics knob).
#[test]
fn small_block_schedules_match_default_block() {
    let reg = registry();
    for seed in 0..8u64 {
        let sched = gen_schedule(seed);
        let (u_toks, u_kv) = run_schedule(&reg, unified_cfg(), &sched);
        let cfg = EngineConfig { kv_block: 4, ..unified_cfg() };
        let (b_toks, b_kv) = run_schedule(&reg, cfg, &sched);
        assert_eq!(u_toks, b_toks, "seed {seed}: kv_block=4 token streams diverged");
        assert_eq!(u_kv, b_kv, "seed {seed}: kv_block=4 spilled-KV bytes diverged");
    }
}

/// The unfused op flow takes the same three-way differential: unified
/// rounds are fusion-agnostic (one fixed schedule keeps this cheap — the
/// fused flow gets the 50-seed sweep above).
#[test]
fn unfused_schedule_matches_across_modes() {
    let reg = registry();
    let sched = Schedule {
        max_concurrent: 3,
        target: 1,
        reqs: [(17usize, 4usize, 0usize), (1, 5, 0), (16, 3, 1), (15, 4, 3), (53, 2, 3)]
            .iter()
            .map(|&(plen, gen, arrival)| Req {
                prompt: (0..plen).map(|t| 23 + (t * 7) % 450).collect(),
                gen,
                arrival,
            })
            .collect(),
    };
    let unfused = |mut cfg: EngineConfig| {
        cfg.fusion = FusionConfig::unfused();
        cfg
    };
    let (u_toks, u_kv) = run_schedule(&reg, unfused(unified_cfg()), &sched);
    let (p_toks, p_kv) = run_schedule(&reg, unfused(spec_cfg()), &sched);
    let (s_toks, s_kv) = run_schedule(&reg, unfused(split_cfg()), &sched);
    let (i_toks, i_kv) = run_schedule(&reg, unfused(interleaved_cfg()), &sched);
    assert_eq!(u_toks, p_toks, "unfused: unified vs speculative diverged");
    assert_eq!(u_toks, s_toks, "unfused: unified vs split diverged");
    assert_eq!(u_toks, i_toks, "unfused: unified vs interleaved diverged");
    assert_eq!(u_kv, p_kv, "unfused: spilled-KV bytes diverged (speculative)");
    assert_eq!(u_kv, s_kv, "unfused: spilled-KV bytes diverged (split)");
    assert_eq!(u_kv, i_kv, "unfused: spilled-KV bytes diverged (interleaved)");
}

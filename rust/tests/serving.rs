//! Serving-engine integration tests: scheduler correctness (interleaved ==
//! sequential), admission control, shared-substrate reuse, and the
//! fixed-cost amortization the serving layer exists for.
//!
//! Everything runs against the built-in manifest + host reference runtime,
//! so the suite is hermetic and deterministic.

use wdb::engine::{Engine, EngineConfig};
use wdb::model::ByteTokenizer;
use wdb::runtime::Registry;
use wdb::serve::{ServeConfig, ServingEngine};

const SEED: u64 = 0x5EBE;

fn registry() -> Registry {
    Registry::builtin().expect("builtin registry")
}

fn tiny_cfg() -> EngineConfig {
    EngineConfig::tiny_fused()
}

/// Acceptance: two interleaved sessions with identical prompts/seeds must
/// produce token streams identical to two sequential single-session runs —
/// no state may leak across sessions through the shared buffer pool,
/// bind-group cache, or pipeline cache.
#[test]
fn interleaved_matches_sequential_identical_prompts() {
    let reg = registry();
    let prompt = ByteTokenizer::new(512).paper_prompt();
    let tokens = 8;

    let mut engine = Engine::new(&reg, tiny_cfg()).unwrap();
    engine.reseed(SEED);
    let a = engine.generate(&prompt, tokens).unwrap();
    let b = engine.generate(&prompt, tokens).unwrap();
    assert_eq!(a.tokens, b.tokens, "sequential runs must be deterministic");

    let mut se = ServingEngine::new(&reg, ServeConfig { engine: tiny_cfg(), max_concurrent: 2 })
        .unwrap();
    se.reseed(SEED);
    se.submit(&prompt, tokens).unwrap();
    se.submit(&prompt, tokens).unwrap();
    se.run_to_completion().unwrap();
    let done = se.drain_finished();
    assert_eq!(done.len(), 2);
    for s in &done {
        assert_eq!(
            s.tokens, a.tokens,
            "interleaved session {} diverged from the sequential stream",
            s.id
        );
    }
}

/// Same property with DIFFERENT prompts — a buffer-pool leak between
/// sessions would corrupt exactly this case.
#[test]
fn interleaved_matches_sequential_distinct_prompts() {
    let reg = registry();
    let pa = vec![65usize, 66, 67];
    let pb = vec![90usize, 91, 92, 93];
    let tokens = 6;

    let mut engine = Engine::new(&reg, tiny_cfg()).unwrap();
    let ra = engine.generate(&pa, tokens).unwrap();
    let rb = engine.generate(&pb, tokens).unwrap();
    assert_ne!(ra.tokens, rb.tokens, "prompts should steer generation");

    let mut se = ServingEngine::new(&reg, ServeConfig { engine: tiny_cfg(), max_concurrent: 2 })
        .unwrap();
    let ida = se.submit(&pa, tokens).unwrap();
    let idb = se.submit(&pb, tokens).unwrap();
    se.run_to_completion().unwrap();
    let done = se.drain_finished();
    let by_id = |id: u64| done.iter().find(|s| s.id == id).expect("session finished");
    assert_eq!(by_id(ida).tokens, ra.tokens, "session A corrupted by interleaving");
    assert_eq!(by_id(idb).tokens, rb.tokens, "session B corrupted by interleaving");
}

/// Acceptance: the serve-bench N=1 path is cost-identical to the existing
/// single-session engine (same substrate path, same jitter draws).
#[test]
fn one_session_serving_matches_engine_numbers() {
    let reg = registry();
    let prompt = ByteTokenizer::new(512).paper_prompt();
    let tokens = 10;

    let mut engine = Engine::new(&reg, tiny_cfg()).unwrap();
    engine.reseed(SEED);
    let gen = engine.generate(&prompt, tokens).unwrap();

    let mut se = ServingEngine::new(&reg, ServeConfig { engine: tiny_cfg(), max_concurrent: 1 })
        .unwrap();
    se.reseed(SEED);
    se.submit(&prompt, tokens).unwrap();
    let report = se.run_to_completion().unwrap();
    let done = se.drain_finished();

    assert_eq!(done[0].tokens, gen.tokens);
    assert_eq!(
        report.wall_virtual_ns, gen.total_ns,
        "serving N=1 virtual wall {} != engine total {}",
        report.wall_virtual_ns, gen.total_ns
    );
    let rel = (report.agg_tok_per_s - gen.tok_per_s).abs() / gen.tok_per_s;
    assert!(rel < 1e-9, "tok/s mismatch: {} vs {}", report.agg_tok_per_s, gen.tok_per_s);
}

/// Satellite: exceeding `max_concurrent` queues rather than erroring, and
/// admission is strictly FIFO.
#[test]
fn excess_requests_queue_fifo() {
    let reg = registry();
    let mut se = ServingEngine::new(&reg, ServeConfig { engine: tiny_cfg(), max_concurrent: 2 })
        .unwrap();
    let mut ids = Vec::new();
    for i in 0..5 {
        let id = se.submit(&[65 + i], 3).expect("submit past capacity must queue");
        ids.push(id);
    }
    assert_eq!(se.queue.len(), 5, "nothing admitted before the first round");
    se.step_round().unwrap();
    assert_eq!(se.active.len(), 2, "cap respected");
    assert_eq!(se.queue.len(), 3);
    assert_eq!(se.active[0].id, ids[0]);
    assert_eq!(se.active[1].id, ids[1]);
    while se.step_round().unwrap() > 0 {
        assert!(se.active.len() <= 2, "max_concurrent violated");
    }
    let done = se.drain_finished();
    assert_eq!(done.len(), 5, "every queued request completes");
    let finished_ids: Vec<u64> = done.iter().map(|s| s.id).collect();
    assert_eq!(finished_ids, ids, "FIFO admission implies FIFO completion here");
}

/// Aggregate throughput must rise with session count: the fixed per-step
/// sync (map cost + GPU-frontier wait) is paid once per interleaved round
/// instead of once per session. Same total work both ways.
#[test]
fn interleaving_amortizes_fixed_sync_cost() {
    let reg = registry();
    let prompt = ByteTokenizer::new(512).paper_prompt();
    let (requests, tokens) = (4usize, 6usize);

    let run = |max_concurrent: usize| {
        let mut se = ServingEngine::new(
            &reg,
            ServeConfig { engine: tiny_cfg(), max_concurrent },
        )
        .unwrap();
        se.reseed(SEED);
        for _ in 0..requests {
            se.submit(&prompt, tokens).unwrap();
        }
        se.run_to_completion().unwrap()
    };

    let serial = run(1);
    let interleaved = run(4);
    assert_eq!(serial.total_tokens, interleaved.total_tokens);
    assert_eq!(serial.dispatches, interleaved.dispatches, "same work");
    assert!(
        interleaved.agg_tok_per_s > serial.agg_tok_per_s,
        "interleaving must amortize fixed sync: {} vs {} tok/s",
        interleaved.agg_tok_per_s,
        serial.agg_tok_per_s
    );
    // The saving is exactly the sync side: per-dispatch + framework costs
    // must NOT shrink (they are per-operation — the paper's wall).
    assert!(
        interleaved.sync_virtual_ns < serial.sync_virtual_ns,
        "sync must amortize: {} vs {}",
        interleaved.sync_virtual_ns,
        serial.sync_virtual_ns
    );
}

/// Satellite (validation): a retired session's pooled buffers are reused by
/// later sessions without any usage-flag or liveness validation errors, and
/// the shared pool keeps buffer creation sublinear in session count.
#[test]
fn retired_session_buffers_recycle_cleanly() {
    let reg = registry();
    let mut se = ServingEngine::new(&reg, ServeConfig { engine: tiny_cfg(), max_concurrent: 2 })
        .unwrap();
    se.submit(&[65, 66], 4).unwrap();
    se.submit(&[70, 71], 4).unwrap();
    se.run_to_completion().unwrap();
    let created_first = se.executor.device.stats.buffers_created;
    assert_eq!(se.executor.device.stats.validation_errors, 0);

    // Two more sessions: must run almost entirely on recycled buffers.
    se.submit(&[80, 81], 4).unwrap();
    se.submit(&[85, 86], 4).unwrap();
    se.run_to_completion().unwrap();
    let created_second = se.executor.device.stats.buffers_created;
    assert_eq!(
        se.executor.device.stats.validation_errors, 0,
        "pooled-buffer reuse across retired sessions must pass validation"
    );
    let growth = created_second - created_first;
    assert!(
        growth < created_first / 2,
        "buffer churn across session batches: {created_first} then +{growth}"
    );
    assert_eq!(se.drain_finished().len(), 4);
}

/// Device-argmax (Appendix H) serving path selects the same tokens as the
/// host-argmax path.
#[test]
fn device_argmax_serving_matches_host_argmax() {
    let reg = registry();
    let prompt = ByteTokenizer::new(512).paper_prompt();
    let tokens = 5;

    let run = |device_argmax: bool| {
        let cfg = EngineConfig { device_argmax, ..tiny_cfg() };
        let mut se = ServingEngine::new(&reg, ServeConfig { engine: cfg, max_concurrent: 2 })
            .unwrap();
        se.submit(&prompt, tokens).unwrap();
        se.submit(&prompt, tokens).unwrap();
        se.run_to_completion().unwrap();
        se.drain_finished()
            .into_iter()
            .map(|s| s.tokens)
            .collect::<Vec<_>>()
    };
    assert_eq!(run(false), run(true), "device argmax changed the token stream");
}

/// Serving rejects malformed requests up-front but keeps serving others.
#[test]
fn submit_validation() {
    let reg = registry();
    let mut se = ServingEngine::new(&reg, ServeConfig { engine: tiny_cfg(), max_concurrent: 1 })
        .unwrap();
    assert!(se.submit(&[], 5).is_err(), "empty prompt");
    assert!(se.submit(&[65], 0).is_err(), "zero tokens");
    assert!(
        se.submit(&[65], 1_000).is_err(),
        "request exceeding KV capacity must be rejected at admission"
    );
    se.submit(&[65], 2).unwrap();
    let r = se.run_to_completion().unwrap();
    assert_eq!(r.total_tokens, 2);
}

/// TTFT accounting: queued sessions accrue queueing delay in TTFT, and
/// per-session metrics stay internally consistent.
#[test]
fn queued_sessions_pay_queueing_in_ttft() {
    let reg = registry();
    let mut se = ServingEngine::new(&reg, ServeConfig { engine: tiny_cfg(), max_concurrent: 1 })
        .unwrap();
    se.submit(&[65], 3).unwrap();
    se.submit(&[66], 3).unwrap();
    se.run_to_completion().unwrap();
    let done = se.drain_finished();
    assert_eq!(done.len(), 2);
    let first = &done[0].metrics;
    let second = &done[1].metrics;
    assert!(second.admitted_ns > first.admitted_ns, "second admitted later");
    assert!(
        second.ttft_ns() > first.ttft_ns(),
        "queued request must show queueing in TTFT: {} vs {}",
        second.ttft_ns(),
        first.ttft_ns()
    );
    for s in &done {
        assert_eq!(s.tokens.len(), 3);
        assert_eq!(s.metrics.per_token_ns.len(), 3);
        assert!(s.metrics.finished_ns >= s.metrics.first_token_ns);
        assert!(s.metrics.dispatches > 0);
    }
}

//! Speculative multi-token decode acceptance suite.
//!
//! The tentpole contract, pinned at fixed seeds: speculation is a
//! SCHEDULING change, never a sampling change — token streams must be
//! bit-identical to plain greedy decode at every acceptance rate — and on
//! the repetitive bench workload (synthetic 32-token prompt, long
//! generation, where greedy decode settles into a short token cycle the
//! order-2/3 n-gram drafter predicts) it must clear the tentpole gates:
//! acceptance >= 0.6 and tokens/round >= 1.5x a --no-speculate twin.
//!
//! Engagement gating is also pinned: speculation rides the unified
//! scheduling path exclusively, so every config that disables unified
//! rounds (eager exec, --no-unified, width/chunk 0, device argmax,
//! single-slot engines) must resolve to speculate = 0 — those paths keep
//! their pre-speculation behavior byte-for-byte.

use wdb::engine::{EngineConfig, ExecMode};
use wdb::runtime::Registry;
use wdb::serve::{ServeConfig, ServeReport, ServingEngine};

/// Same fixed seed the serve bench uses for rows and twins.
const SEED: u64 = 0x5EBE;

fn registry() -> Registry {
    Registry::builtin().expect("builtin registry")
}

fn cfg(speculate: usize) -> EngineConfig {
    EngineConfig { exec: ExecMode::Planned, speculate, ..EngineConfig::tiny_fused() }
}

/// The serve bench's synthetic prompt (`--prompt N`).
fn synth_prompt(n: usize) -> Vec<usize> {
    (0..n).map(|i| 32 + (i * 7) % 200).collect()
}

/// Build, reseed, submit `requests`, run dry. Returns the per-request
/// token streams (submission order) and the report.
fn run(
    reg: &Registry,
    cfg: EngineConfig,
    max_concurrent: usize,
    requests: &[(Vec<usize>, usize)],
) -> (Vec<Vec<usize>>, ServeReport) {
    let mut se = ServingEngine::new(reg, ServeConfig { engine: cfg, max_concurrent })
        .expect("serving engine");
    se.reseed(SEED);
    let ids: Vec<u64> = requests
        .iter()
        .map(|(prompt, tokens)| se.submit(prompt, *tokens).expect("submit"))
        .collect();
    let report = se.run_to_completion().expect("run");
    let done = se.drain_finished();
    let toks = ids
        .iter()
        .map(|id| done.iter().find(|s| s.id == *id).expect("finished").tokens.clone())
        .collect();
    (toks, report)
}

/// The tentpole gate, at the bench's fixed seed: on the repetitive
/// workload, speculation emits >= 1.5x the tokens per round of a plain
/// twin at >= 0.6 acceptance, with bit-identical token streams.
#[test]
fn repetitive_workload_clears_acceptance_and_throughput_gates() {
    let reg = registry();
    let reqs: Vec<(Vec<usize>, usize)> = vec![(synth_prompt(32), 120); 4];
    let (spec_toks, sr) = run(&reg, cfg(4), 4, &reqs);
    let (plain_toks, pr) = run(&reg, cfg(0), 4, &reqs);
    assert_eq!(spec_toks, plain_toks, "speculation changed the token streams");
    assert!(
        sr.acceptance_rate() >= 0.6,
        "acceptance {:.2} < 0.6 ({} drafted / {} accepted)",
        sr.acceptance_rate(),
        sr.drafted,
        sr.accepted
    );
    assert!(
        sr.tokens_per_round() >= 1.5 * pr.tokens_per_round(),
        "tokens/round {:.2} < 1.5 x plain {:.2} ({} vs {} rounds)",
        sr.tokens_per_round(),
        pr.tokens_per_round(),
        sr.rounds,
        pr.rounds
    );
}

/// Identity must hold regardless of acceptance: a short non-repetitive
/// prompt (the paper's serve workload shape) drafts little or nothing,
/// and the streams still match bit-for-bit.
#[test]
fn non_repetitive_streams_stay_bit_identical() {
    let reg = registry();
    let reqs: Vec<(Vec<usize>, usize)> = (0..3)
        .map(|i| ((0..5 + i).map(|t| 40 + (t * 11 + i) % 300).collect(), 12))
        .collect();
    let (spec_toks, sr) = run(&reg, cfg(4), 3, &reqs);
    let (plain_toks, _) = run(&reg, cfg(0), 3, &reqs);
    assert_eq!(spec_toks, plain_toks);
    assert_eq!(sr.speculate, 4, "unified path should have engaged speculation");
}

/// Draft length clamps so committed token + draft always fit the chunk
/// and the KV capacity: near-max_seq sessions and tiny generation budgets
/// must not overrun (and stay identical to plain decode).
#[test]
fn draft_length_clamps_at_sequence_and_generation_limits() {
    let reg = registry();
    // prompt + gen - 1 = 159 = max_seq - 1: the tightest admissible fit.
    let near_cap = vec![(synth_prompt(150), 10); 2];
    let (s, _) = run(&reg, cfg(4), 2, &near_cap);
    let (p, _) = run(&reg, cfg(0), 2, &near_cap);
    assert_eq!(s, p, "near-capacity sessions diverged");
    // remaining - 1 = 1: at most one draft row per round is admissible.
    let tiny_gen = vec![(synth_prompt(32), 2); 4];
    let (s, _) = run(&reg, cfg(4), 4, &tiny_gen);
    let (p, _) = run(&reg, cfg(0), 4, &tiny_gen);
    assert_eq!(s, p, "tiny-generation sessions diverged");
}

/// ServeReport plumbs the speculative counters and labels the mode.
#[test]
fn report_counts_drafts_and_labels_the_mode() {
    let reg = registry();
    let reqs: Vec<(Vec<usize>, usize)> = vec![(synth_prompt(32), 120); 2];
    let (_, r) = run(&reg, cfg(4), 2, &reqs);
    assert_eq!(r.speculate, 4);
    assert!(r.drafted > 0, "repetitive workload should draft");
    assert!(r.accepted > 0, "repetitive workload should accept");
    assert!(r.accepted <= r.drafted);
    assert!(r.acceptance_rate() > 0.0 && r.acceptance_rate() <= 1.0);
    assert!(
        r.mode_label().contains("+spec(k=4)"),
        "mode label missing speculation: {}",
        r.mode_label()
    );
    // Plain runs advertise no speculation and count nothing.
    let (_, r0) = run(&reg, cfg(0), 2, &reqs[..1]);
    assert_eq!((r0.speculate, r0.drafted, r0.accepted), (0, 0, 0));
    assert!(!r0.mode_label().contains("+spec"));
}

/// Speculation rides the unified path only: every config that disables
/// unified rounds resolves to speculate = 0.
#[test]
fn speculation_disengages_off_the_unified_path() {
    let reg = registry();
    let off = [
        EngineConfig { exec: ExecMode::Eager, ..cfg(4) },
        EngineConfig { unified: false, ..cfg(4) },
        EngineConfig { batch_width: 0, ..cfg(4) },
        EngineConfig { prefill_chunk: 0, ..cfg(4) },
        EngineConfig { device_argmax: true, ..cfg(4) },
    ];
    for ec in off {
        let se = ServingEngine::new(&reg, ServeConfig { engine: ec, max_concurrent: 4 })
            .expect("serving engine");
        assert_eq!(se.speculate, 0);
    }
    // Single-slot engines never batch, so they never speculate either.
    let se = ServingEngine::new(&reg, ServeConfig { engine: cfg(4), max_concurrent: 1 })
        .expect("serving engine");
    assert_eq!(se.speculate, 0);
    // And the engaged path clamps the draft length into one chunk.
    let se = ServingEngine::new(
        &reg,
        ServeConfig {
            engine: EngineConfig { prefill_chunk: 8, ..cfg(99) },
            max_concurrent: 4,
        },
    )
    .expect("serving engine");
    assert_eq!(se.speculate, 7, "speculate must clamp to prefill_chunk - 1");
}

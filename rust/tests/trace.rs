//! Tracer acceptance tests: the observability layer must be free —
//! switching sinks (Null / Ring / Chrome) may never move a token, a
//! dispatch, or a virtual nanosecond — and the exported Chrome trace must
//! be well-formed and complete enough to reconstruct the serving
//! timeline (the "tiling proof": summing `round` spans out of the trace
//! reproduces the report's wall clock).
//!
//! Everything runs the mixed serving workload below: staggered prompt
//! lengths spanning the chunking equivalence classes, so rounds mix
//! prefill chunks and decode steps the way the paper's serving
//! experiments do.

use std::time::Instant;

use wdb::engine::{EngineConfig, ExecMode};
use wdb::fx::builder::FusionConfig;
use wdb::runtime::Registry;
use wdb::serve::{ServeConfig, ServeReport, ServingEngine};
use wdb::trace::{TraceConfig, TraceSinkKind};

const SEED: u64 = 0x7ACE;

fn registry() -> Registry {
    Registry::builtin().expect("builtin registry")
}

fn cfg_with(sink: TraceSinkKind, ring: usize) -> EngineConfig {
    EngineConfig {
        fusion: FusionConfig::fused(),
        exec: ExecMode::Planned,
        trace: TraceConfig { sink, ring },
        ..EngineConfig::tiny_fused()
    }
}

/// Mixed workload: prompt lengths straddle the prefill chunk (16) so the
/// run has chunked-prefill rounds, mixed rounds, and pure decode rounds.
const WORKLOAD: &[(usize, usize)] =
    &[(24, 6), (15, 5), (16, 4), (33, 6), (1, 5), (17, 4)];

fn prompt(plen: usize, salt: usize) -> Vec<usize> {
    (0..plen).map(|t| 9 + (t * 13 + salt * 31) % 450).collect()
}

/// Build, run, and drain one serving engine over the mixed workload.
/// Returns per-request token streams plus the report; the engine is
/// handed back so Chrome-sink callers can export before dropping it.
fn run(
    reg: &Registry,
    sink: TraceSinkKind,
    ring: usize,
) -> (Vec<Vec<usize>>, ServeReport, ServingEngine<'_>) {
    let mut se = ServingEngine::new(
        reg,
        ServeConfig { engine: cfg_with(sink, ring), max_concurrent: 4 },
    )
    .expect("serving engine");
    se.reseed(SEED);
    let mut ids = Vec::with_capacity(WORKLOAD.len());
    for (i, &(plen, gen)) in WORKLOAD.iter().enumerate() {
        ids.push(se.submit(&prompt(plen, i), gen).expect("submit"));
    }
    let report = se.run_to_completion().expect("run");
    let done = se.drain_finished();
    let toks = ids
        .iter()
        .map(|id| done.iter().find(|s| s.id == *id).expect("finished").tokens.clone())
        .collect();
    (toks, report, se)
}

/// Sink independence: Null vs Ring vs Chrome produce bit-identical token
/// streams, dispatch counts, and virtual wall clocks — instrumentation
/// only reads the clock. Then the overhead gate: a live ring sink must
/// cost at most 5% extra real wall time (min-of-5 per sink, interleaved
/// so machine drift hits both alike, plus a 20 ms absolute floor so
/// timer noise on sub-100 ms debug runs cannot flake the gate).
#[test]
fn ring_sink_is_free_and_within_overhead_budget() {
    let reg = registry();
    let (n_toks, n_rep, _) = run(&reg, TraceSinkKind::Null, 0);
    let (r_toks, r_rep, se) = run(&reg, TraceSinkKind::Ring, 1 << 18);
    assert_eq!(n_toks, r_toks, "ring sink moved a token");
    assert_eq!(n_rep.dispatches, r_rep.dispatches, "ring sink changed dispatch count");
    assert_eq!(n_rep.rounds, r_rep.rounds, "ring sink changed round count");
    assert_eq!(
        n_rep.wall_virtual_ns, r_rep.wall_virtual_ns,
        "ring sink advanced the virtual clock"
    );
    assert!(r_rep.trace_events > 0, "ring sink retained nothing");
    assert_eq!(r_rep.trace_dropped_events, 0, "test ring wrapped");
    wdb::trace::validate_balance(&se.tracer().drain()).expect("balanced span stacks");
    drop(se);

    let mut null_min = f64::INFINITY;
    let mut ring_min = f64::INFINITY;
    for _ in 0..5 {
        let t0 = Instant::now();
        let _ = run(&reg, TraceSinkKind::Null, 0);
        null_min = null_min.min(t0.elapsed().as_secs_f64());
        let t0 = Instant::now();
        let _ = run(&reg, TraceSinkKind::Ring, 1 << 18);
        ring_min = ring_min.min(t0.elapsed().as_secs_f64());
    }
    assert!(
        ring_min <= null_min * 1.05 + 0.020,
        "ring-sink overhead gate failed: min wall {ring_min:.4}s vs null \
         {null_min:.4}s (> 5% + 20ms)"
    );
}

/// Chrome export shape: the document round-trips the validator, carries
/// one lane per batch slot, names dispatches after their fx nodes, and
/// counts one `token` instant per generated token.
#[test]
fn chrome_export_has_slot_tracks_op_names_and_token_instants() {
    let reg = registry();
    let (_, report, se) = run(&reg, TraceSinkKind::Chrome, 0);
    let doc = se.export_chrome_trace(&report);
    let stats = wdb::trace::chrome::validate(&doc).expect("exported trace must validate");
    assert!(stats.span_pairs > 0, "no B/E spans exported");
    assert!(
        stats.slot_tracks >= 2,
        "expected at least 2 slot lanes, got {}",
        stats.slot_tracks
    );

    let events = doc.req("traceEvents").expect("traceEvents").as_arr().expect("array");
    let name_of = |ev: &wdb::report::json::Value| {
        ev.get("name").and_then(|n| n.as_str().map(str::to_string)).unwrap_or_default()
    };
    for well_known in ["round", "chunk", "replay", "token"] {
        assert!(
            events.iter().any(|e| name_of(e) == well_known),
            "exported trace is missing '{well_known}' events"
        );
    }
    assert!(
        events.iter().any(|e| name_of(e).contains("q_proj")),
        "dispatch events should carry fx node names (expected a *q_proj*)"
    );
    let token_instants = events
        .iter()
        .filter(|e| {
            name_of(e) == "token"
                && e.get("ph").and_then(|p| p.as_str()) == Some("i")
        })
        .count();
    assert_eq!(
        token_instants, report.total_tokens,
        "one token instant per generated token"
    );
    let round_spans = events
        .iter()
        .filter(|e| {
            name_of(e) == "round" && e.get("ph").and_then(|p| p.as_str()) == Some("B")
        })
        .count();
    assert_eq!(round_spans as u64, report.rounds, "one round span per round");

    // Serialize + reparse survives the validator too (what trace-summary
    // reads back off disk).
    let text = wdb::report::json::to_string_pretty(&doc);
    let doc2 = wdb::report::json::parse(&text).expect("reparse");
    wdb::trace::chrome::validate(&doc2).expect("reparsed trace must validate");
}

/// The tiling proof: `round` spans cover the serving loop's virtual wall
/// exactly, so trace-summary's reconstruction must land within 1% of the
/// report (here it should be exact — rounds abut with no gaps).
#[test]
fn round_spans_tile_the_report_wall() {
    let reg = registry();
    let (_, report, se) = run(&reg, TraceSinkKind::Chrome, 0);
    let doc = se.export_chrome_trace(&report);
    let sum = wdb::trace::summary::summarize(&doc).expect("summarize");
    let delta = sum.tiling_delta().expect("exporter records wall_virtual_ns");
    assert!(
        delta <= 0.01,
        "round spans reconstruct {:.3} ms but the report wall was {:.3} ms \
         (delta {:.3}% > 1%)",
        sum.round_span_ns / 1e6,
        report.wall_virtual_ns as f64 / 1e6,
        delta * 100.0
    );
    // T1 renders and names the dominant phases.
    let md = sum.table().to_markdown();
    assert!(md.contains("### T1"), "{md}");
    assert!(md.contains("round"), "{md}");
    assert!(md.contains("Tiling check"), "{md}");
}

/// Report-side histograms: recorded regardless of sink (percentiles never
/// depend on event retention), percentile accessors are ordered, and the
/// round histogram saw every round.
#[test]
fn report_histograms_record_under_the_null_sink() {
    let reg = registry();
    let (_, report, _) = run(&reg, TraceSinkKind::Null, 0);
    assert_eq!(report.round_hist.count(), report.rounds, "one sample per round");
    assert!(report.ttft_hist.count() > 0, "TTFT histogram empty");
    assert!(report.itl_hist.count() > 0, "ITL histogram empty");
    assert!(report.ttft_p50_ms() > 0.0);
    assert!(report.ttft_p50_ms() <= report.ttft_p90_ms());
    assert!(report.ttft_p90_ms() <= report.ttft_p99_ms());
    assert!(report.itl_p50_ms() > 0.0);
    assert!(report.itl_p50_ms() <= report.itl_p99_ms());
    // The log-bucketed histogram quantizes within its bucket width:
    // p50 tracks the exact mean within the paper's +/-6.25% bound scaled
    // by the TTFT spread across the mixed workload.
    assert!(report.mean_ttft_ms > 0.0);
}

//! Unified-round conformance tests: the dispatch census (expected counts
//! vs runner-recorded counts), masked-slot edge cases (padding slots,
//! all-prefill / all-decode / single-session rounds, retire-and-replace
//! churn), the readback-membership rule, and the engagement gates.
//!
//! Everything runs against the built-in manifest + host reference runtime
//! — hermetic and deterministic.

use wdb::engine::{EngineConfig, ExecMode};
use wdb::fx::builder::{
    expected_batched_dispatches, expected_prefill_dispatches, expected_unified_dispatches,
    FusionConfig,
};
use wdb::runtime::Registry;
use wdb::serve::{ServeConfig, ServingEngine};

const SEED: u64 = 0x07F1;

fn registry() -> Registry {
    Registry::builtin().expect("builtin registry")
}

fn cfg(fusion: FusionConfig) -> EngineConfig {
    EngineConfig { fusion, exec: ExecMode::Planned, ..EngineConfig::tiny_fused() }
}

fn prompt_of(len: usize) -> Vec<usize> {
    (0..len).map(|i| 33 + (i * 11) % 400).collect()
}

fn engine(reg: &Registry, config: EngineConfig, max_concurrent: usize) -> ServingEngine<'_> {
    let mut se = ServingEngine::new(reg, ServeConfig { engine: config, max_concurrent })
        .expect("serving engine");
    se.reseed(SEED);
    se
}

/// Dispatch census, runner-recorded: with one chunk-of-slots, EVERY
/// unified round — the all-prefill first round included — costs exactly
/// `expected_unified_dispatches`, for both fusion configs. The fused
/// count is the batched 59 plus the one slot-last-row selection dispatch.
#[test]
fn unified_round_dispatches_match_expected_census() {
    let reg = registry();
    assert_eq!(expected_unified_dispatches(&wdb::fx::builder::GraphDims::qwen_tiny(),
        FusionConfig::fused()), 60);
    // The census is constant in both W and C (one dispatch per layer op,
    // never per session or per row) — sweep chunk sizes and both fusion
    // configs; width sweeps live in the wide-round and gating tests.
    for (fusion, chunk) in [
        (FusionConfig::unfused(), 16),
        (FusionConfig::fused(), 8),
        (FusionConfig::fused(), 16),
        (FusionConfig::fused(), 32),
    ] {
        let mut se = engine(&reg, EngineConfig { prefill_chunk: chunk, ..cfg(fusion) }, 4);
        let expected = expected_unified_dispatches(&se.dims, fusion) as u64;
        for _ in 0..4 {
            se.submit(&prompt_of(5), 4).expect("submit");
        }
        let mut rounds = 0u64;
        loop {
            let d0 = se.executor.dispatch_count;
            if se.step_round().expect("step_round") == 0 {
                break;
            }
            rounds += 1;
            assert_eq!(
                se.executor.dispatch_count - d0,
                expected,
                "{fusion:?} chunk {chunk} round {rounds}: a unified round is ONE replay"
            );
        }
        // prompt 5 = one prefill chunk at every chunk size, then 3 decode
        // rounds (identical prompts retire together).
        assert_eq!(rounds, 4, "{fusion:?} chunk {chunk}");
    }
}

/// Census for the split-scheduling twins the unified path subsumes:
/// chunked-prefill rounds record `expected_prefill_dispatches` and
/// batched decode rounds record `expected_batched_dispatches` per replay.
#[test]
fn split_mode_dispatches_match_expected_census() {
    let reg = registry();
    let fusion = FusionConfig::fused();

    // Prefill rounds: one session, prompt = 2 chunks, 1 generated token.
    let mut se = engine(&reg, EngineConfig { unified: false, ..cfg(fusion) }, 1);
    let exp_prefill = expected_prefill_dispatches(&se.dims, fusion) as u64;
    se.submit(&prompt_of(32), 1).expect("submit");
    for round in 0..2 {
        let d0 = se.executor.dispatch_count;
        se.step_round().expect("step_round");
        assert_eq!(se.executor.dispatch_count - d0, exp_prefill, "prefill round {round}");
    }
    assert!(se.active.is_empty(), "2 chunks + 1 token = exactly 2 rounds");

    // Batched decode rounds: 4 one-token prompts, prefill chunking off.
    let mut se = engine(&reg, EngineConfig { unified: false, prefill_chunk: 0, ..cfg(fusion) }, 4);
    let exp_batched = expected_batched_dispatches(&se.dims, fusion) as u64;
    for t in 0..4usize {
        se.submit(&[40 + t], 3).expect("submit");
    }
    loop {
        let d0 = se.executor.dispatch_count;
        if se.step_round().expect("step_round") == 0 {
            break;
        }
        assert_eq!(se.executor.dispatch_count - d0, exp_batched, "batched round");
    }
}

/// Oversubscription past the kernel batch width: 6 sessions over width-4
/// replays pack TWO chunk-of-slots per round — 2x the unified census,
/// never per-session work. The second chunk carries two live slots and
/// two `valid_len = 0` padding slots.
#[test]
fn wide_rounds_cost_one_replay_per_chunk_of_slots() {
    let reg = registry();
    let fusion = FusionConfig::fused();
    let mut se = engine(&reg, cfg(fusion), 6);
    let expected = expected_unified_dispatches(&se.dims, fusion) as u64;
    for t in 0..6usize {
        se.submit(&[50 + t], 3).expect("submit");
    }
    loop {
        let d0 = se.executor.dispatch_count;
        if se.step_round().expect("step_round") == 0 {
            break;
        }
        assert_eq!(
            se.executor.dispatch_count - d0,
            2 * expected,
            "6 slots / width 4 = 2 replays per round"
        );
    }
    let runner = se.executor.unified_runner().expect("unified plan enabled");
    assert_eq!(runner.width(), 4);
    assert_eq!(runner.chunk(), 16);
}

/// A whole unified run is self-describing: the report carries the
/// unified flag, the subsuming mode label, and a dispatches/round equal
/// to the census (constant-membership run, one chunk-of-slots).
#[test]
fn unified_report_reflects_census_and_mode() {
    let reg = registry();
    let fusion = FusionConfig::fused();
    let mut se = engine(&reg, cfg(fusion), 4);
    let expected = expected_unified_dispatches(&se.dims, fusion) as u64;
    for _ in 0..4 {
        se.submit(&prompt_of(5), 4).expect("submit");
    }
    let report = se.run_to_completion().expect("serve");
    assert!(report.unified);
    assert_eq!(report.mode_label(), "planned+unified(w=4,c=16)");
    assert_eq!(report.dispatches, report.rounds * expected);
    assert!((report.dispatches_per_round() - expected as f64).abs() < 1e-9);
    // Step accounting stays token-granular through unified rounds.
    assert_eq!(report.prefill_steps, 4 * 5);
    assert_eq!(report.steps, 4 * (5 + 4 - 1));
}

/// Masked-slot edge case: a single active session in a width-4 engine
/// still rounds through the unified replay (three `valid_len = 0`
/// padding slots), costs exactly the census — no per-slot work for
/// padding — and stays bit-identical to the interleaved engine.
#[test]
fn single_active_session_rounds_stay_unified_and_identical() {
    let reg = registry();
    let fusion = FusionConfig::fused();

    let mut se = engine(&reg, cfg(fusion), 4);
    let expected = expected_unified_dispatches(&se.dims, fusion) as u64;
    se.submit(&prompt_of(20), 5).expect("submit");
    loop {
        let d0 = se.executor.dispatch_count;
        if se.step_round().expect("step_round") == 0 {
            break;
        }
        assert_eq!(se.executor.dispatch_count - d0, expected, "padding slots must be free");
    }
    let unified: Vec<usize> = se.drain_finished().remove(0).tokens;

    let mut se = engine(
        &reg,
        EngineConfig { batch_width: 0, prefill_chunk: 0, ..cfg(fusion) },
        4,
    );
    se.submit(&prompt_of(20), 5).expect("submit");
    se.run_to_completion().expect("serve");
    assert_eq!(
        unified,
        se.drain_finished().remove(0).tokens,
        "single-session unified rounds diverged from interleaved"
    );
}

/// Readback membership: rounds whose members are ALL intermediate prompt
/// chunks never synchronize (no logits are live); the round that carries
/// a final chunk or a decode step pays the one coalesced sync.
#[test]
fn intermediate_prefill_rounds_skip_readback() {
    let reg = registry();
    let mut se = engine(&reg, cfg(FusionConfig::fused()), 2);
    // Two 40-token prompts: rounds 1-2 are all-intermediate chunks
    // (16 + 16 rows), round 3 is the final ragged chunk (8 rows) that
    // produces both first tokens.
    se.submit(&prompt_of(40), 2).expect("submit");
    se.submit(&prompt_of(40), 2).expect("submit");
    let s0 = se.executor.device.timeline.sync_virtual_ns;
    se.step_round().expect("round 1");
    se.step_round().expect("round 2");
    assert_eq!(
        se.executor.device.timeline.sync_virtual_ns, s0,
        "all-intermediate rounds must not synchronize"
    );
    se.step_round().expect("round 3");
    assert!(
        se.executor.device.timeline.sync_virtual_ns > s0,
        "the final-chunk round pays the round's one readback"
    );
}

/// Retire-and-replace churn across unified rounds: mixed prompt lengths
/// and generation lengths, ragged masked chunk tails, and a queued 4th
/// request that takes the retired session's slot (and its LIFO-recycled
/// cache set) — with ZERO pipelines created after engine construction
/// and ONE registered cache-set table. Lifetimes are crafted so every
/// round keeps all three slots covered (a padding-bound slot is a
/// DIFFERENT table key, legitimately so — this pins the steady churn
/// shape): a/c/d all retire together in round 6.
#[test]
fn churned_rounds_create_no_pipelines_and_one_table() {
    let reg = registry();
    let mut se = engine(&reg, cfg(FusionConfig::fused()), 3);
    // Round-by-round: r1 a-chunk(16)+b-t1+c-t1; r2 a-final(4)->t1,
    // b-t2 retires; r3 d admitted into slot 1, d-chunk(16); r4
    // d-final(1)->t1; r5-r6 all-decode; a, c, d finish in round 6.
    let ida = se.submit(&prompt_of(20), 5).expect("submit a");
    let idb = se.submit(&[90], 2).expect("submit b");
    let idc = se.submit(&prompt_of(5), 6).expect("submit c");
    let idd = se.submit(&prompt_of(17), 3).expect("submit d (queued until b retires)");
    let pipes0 = se.executor.device.stats.pipelines_created;
    let report = se.run_to_completion().expect("serve");
    assert_eq!(report.rounds, 6);
    assert_eq!(
        se.executor.device.stats.pipelines_created, pipes0,
        "masked ragged tails + churn must not recompile"
    );
    let runner = se.executor.unified_runner().expect("unified plan enabled");
    assert_eq!(
        runner.registered_tables(),
        1,
        "sticky slots + recycled cache sets must keep ONE table across churn"
    );
    let done = se.drain_finished();
    assert_eq!(done.len(), 4);
    let slot_of = |id: u64| done.iter().find(|s| s.id == id).unwrap().slot;
    assert_eq!(slot_of(ida), Some(0));
    assert_eq!(slot_of(idb), Some(1));
    assert_eq!(slot_of(idc), Some(2));
    assert_eq!(slot_of(idd), Some(1), "replacement admission reuses the freed slot");
}

/// Engagement gates: unified rounds require planned exec, batching,
/// chunked prefill, host-side argmax, and >= 2 concurrent slots; the
/// default serving config engages them, and `unified: false` falls back
/// to split scheduling with the batched/prefill graphs still available.
#[test]
fn unified_gates_on_mode_width_chunk_argmax_and_concurrency() {
    let reg = registry();
    let fused = FusionConfig::fused();

    let on = engine(&reg, cfg(fused), 2);
    assert!(on.unified_graph.is_some(), "serving default must engage unified rounds");
    assert!(on.executor.unified_runner().is_some());
    assert_eq!(on.executor.unified_runner().unwrap().width(), 2, "width clamps to slots");

    let off = engine(&reg, EngineConfig { unified: false, ..cfg(fused) }, 2);
    assert!(off.unified_graph.is_none(), "--no-unified must fall back to split");
    assert!(off.batched_graph.is_some());
    assert!(off.prefill_graph.is_some());

    let eager = engine(
        &reg,
        EngineConfig { exec: ExecMode::Eager, ..EngineConfig::tiny_fused() },
        2,
    );
    assert!(eager.unified_graph.is_none(), "eager engines must not unify");

    let argmax = engine(
        &reg,
        EngineConfig { device_argmax: true, ..cfg(fused) },
        2,
    );
    assert!(argmax.unified_graph.is_none(), "device-argmax finish keeps split rounds");

    let single = engine(&reg, cfg(fused), 1);
    assert!(single.unified_graph.is_none(), "1-slot engines have nothing to batch");

    let no_batch = engine(&reg, EngineConfig { batch_width: 0, ..cfg(fused) }, 4);
    assert!(no_batch.unified_graph.is_none(), "--no-batch disables unified rounds");

    let no_chunk = engine(&reg, EngineConfig { prefill_chunk: 0, ..cfg(fused) }, 4);
    assert!(no_chunk.unified_graph.is_none(), "--prefill-chunk 0 disables unified rounds");
}

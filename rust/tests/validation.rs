//! Failure-injection tests for the WebGPU substrate's validation layer —
//! the per-operation checks whose cost the paper characterizes must
//! actually enforce the API contract (and never panic).

use wdb::tensor::DType;
use wdb::webgpu::queue::{bind_buffers, kernel_layout, run_kernel_dispatch, DispatchBatcher};
use wdb::webgpu::{
    BindGroupDesc, BindGroupLayoutDesc, BindingType, BufferDesc, BufferUsage, Device,
    ImplementationProfile, KernelIoSpec, Limits, NullRunner, ShaderModuleDesc,
};

fn device() -> Device {
    Device::new(ImplementationProfile::zero_overhead())
}

fn spec64() -> KernelIoSpec {
    KernelIoSpec { shape: vec![64], dtype: DType::F32 }
}

fn storage_buffer(dev: &mut Device, size: usize) -> wdb::webgpu::BufferId {
    dev.create_buffer(BufferDesc {
        label: "b".into(),
        size,
        usage: BufferUsage::STORAGE | BufferUsage::COPY_DST | BufferUsage::MAP_READ,
    })
    .unwrap()
}

// ------------------------------------------------------------- buffers ----
#[test]
fn zero_size_buffer_rejected() {
    let mut dev = device();
    let r = dev.create_buffer(BufferDesc {
        label: "z".into(),
        size: 0,
        usage: BufferUsage::STORAGE,
    });
    assert!(r.is_err());
    assert_eq!(dev.stats.validation_errors, 1);
}

#[test]
fn oversized_buffer_rejected() {
    let mut dev = Device::with_limits(ImplementationProfile::zero_overhead(), Limits::tiny());
    let r = dev.create_buffer(BufferDesc {
        label: "big".into(),
        size: 4096, // tiny limit is 1 KiB
        usage: BufferUsage::STORAGE,
    });
    assert!(matches!(r, Err(wdb::Error::LimitExceeded(_))));
}

#[test]
fn empty_usage_rejected() {
    let mut dev = device();
    assert!(dev
        .create_buffer(BufferDesc { label: "u".into(), size: 16, usage: BufferUsage(0) })
        .is_err());
}

#[test]
fn write_requires_copy_dst() {
    let mut dev = device();
    let b = dev
        .create_buffer(BufferDesc {
            label: "ro".into(),
            size: 16,
            usage: BufferUsage::STORAGE,
        })
        .unwrap();
    assert!(dev.write_buffer(b, 0, &[0u8; 8]).is_err());
}

#[test]
fn write_out_of_bounds_rejected() {
    let mut dev = device();
    let b = storage_buffer(&mut dev, 16);
    assert!(dev.write_buffer(b, 12, &[0u8; 8]).is_err());
    assert!(dev.write_buffer(b, 0, &[0u8; 16]).is_ok());
}

#[test]
fn destroyed_buffer_unusable() {
    let mut dev = device();
    let b = storage_buffer(&mut dev, 16);
    dev.destroy_buffer(b).unwrap();
    assert!(dev.write_buffer(b, 0, &[0u8; 4]).is_err());
    assert!(dev.map_read(b).is_err());
    assert!(dev.buffer_size(b).is_err());
}

#[test]
fn map_read_requires_usage() {
    let mut dev = device();
    let b = dev
        .create_buffer(BufferDesc {
            label: "nm".into(),
            size: 16,
            usage: BufferUsage::STORAGE,
        })
        .unwrap();
    assert!(dev.map_read(b).is_err());
}

// ---------------------------------------------------------- bind groups ----
#[test]
fn bind_group_entry_count_must_match_layout() {
    let mut dev = device();
    let b = storage_buffer(&mut dev, 256);
    let layout = dev
        .create_bind_group_layout(BindGroupLayoutDesc {
            label: "l".into(),
            entries: vec![BindingType::ReadOnlyStorage, BindingType::Storage],
        })
        .unwrap();
    // bind only one buffer -> mismatch
    let r = bind_buffers(&mut dev, "g", layout, &[b], &[]);
    assert!(r.is_err());
}

#[test]
fn bind_group_usage_mismatch_rejected() {
    let mut dev = device();
    let uniform_only = dev
        .create_buffer(BufferDesc {
            label: "uni".into(),
            size: 64,
            usage: BufferUsage::UNIFORM,
        })
        .unwrap();
    let layout = dev
        .create_bind_group_layout(BindGroupLayoutDesc {
            label: "l".into(),
            entries: vec![BindingType::Storage],
        })
        .unwrap();
    let r = dev.create_bind_group(BindGroupDesc {
        label: "g".into(),
        layout,
        entries: vec![wdb::webgpu::bindgroup::BindGroupEntry {
            binding: 0,
            buffer: uniform_only,
            offset: 0,
            size: 64,
        }],
    });
    assert!(r.is_err());
}

#[test]
fn bind_group_range_out_of_bounds_rejected() {
    let mut dev = device();
    let b = storage_buffer(&mut dev, 64);
    let layout = dev
        .create_bind_group_layout(BindGroupLayoutDesc {
            label: "l".into(),
            entries: vec![BindingType::Storage],
        })
        .unwrap();
    let r = dev.create_bind_group(BindGroupDesc {
        label: "g".into(),
        layout,
        entries: vec![wdb::webgpu::bindgroup::BindGroupEntry {
            binding: 0,
            buffer: b,
            offset: 32,
            size: 64, // 32 + 64 > 64
        }],
    });
    assert!(r.is_err());
}

#[test]
fn too_many_bindings_rejected() {
    let mut dev = Device::with_limits(ImplementationProfile::zero_overhead(), Limits::tiny());
    let r = dev.create_bind_group_layout(BindGroupLayoutDesc {
        label: "l".into(),
        entries: vec![BindingType::Storage; 3], // tiny limit is 2
    });
    assert!(matches!(r, Err(wdb::Error::LimitExceeded(_))));
}

// ------------------------------------------------------------ pipeline ----
#[test]
fn pipeline_interface_must_match_layout() {
    let mut dev = device();
    let module = dev
        .create_shader_module(ShaderModuleDesc {
            label: "k".into(),
            kernel: "k".into(),
            inputs: vec![spec64(), spec64()],
            outputs: vec![spec64()],
        })
        .unwrap();
    // layout with wrong binding count
    let bad = dev
        .create_bind_group_layout(BindGroupLayoutDesc {
            label: "bad".into(),
            entries: vec![BindingType::ReadOnlyStorage, BindingType::Storage],
        })
        .unwrap();
    assert!(dev.create_compute_pipeline("p", module, bad).is_err());
    // layout with writable input
    let wrong_rw = dev
        .create_bind_group_layout(BindGroupLayoutDesc {
            label: "rw".into(),
            entries: vec![BindingType::Storage, BindingType::Storage, BindingType::Storage],
        })
        .unwrap();
    assert!(dev.create_compute_pipeline("p", module, wrong_rw).is_err());
    // correct layout
    let good = kernel_layout(&mut dev, "good", 2, 1).unwrap();
    assert!(dev.create_compute_pipeline("p", module, good).is_ok());
}

// ---------------------------------------------------- encoder lifecycle ----
#[test]
fn dispatch_requires_pipeline_and_bind_group() {
    let mut dev = device();
    let enc = dev.create_command_encoder("e");
    dev.begin_compute_pass(enc).unwrap();
    assert!(dev.dispatch_workgroups(enc, 1, 1, 1).is_err()); // no pipeline
}

#[test]
fn dispatch_outside_pass_rejected() {
    let mut dev = device();
    let enc = dev.create_command_encoder("e");
    assert!(dev.dispatch_workgroups(enc, 1, 1, 1).is_err());
}

#[test]
fn zero_and_oversized_workgroups_rejected() {
    let mut dev = device();
    let (pipeline, layout, b_in, b_out) = trivial_pipeline(&mut dev);
    let group = bind_buffers(&mut dev, "g", layout, &[b_in], &[b_out]).unwrap();
    let enc = dev.create_command_encoder("e");
    dev.begin_compute_pass(enc).unwrap();
    dev.set_pipeline(enc, pipeline).unwrap();
    dev.set_bind_group(enc, group).unwrap();
    assert!(dev.dispatch_workgroups(enc, 0, 1, 1).is_err());
    assert!(dev.dispatch_workgroups(enc, 70_000, 1, 1).is_err());
    assert!(dev.dispatch_workgroups(enc, 1, 1, 1).is_ok());
}

#[test]
fn finish_with_open_pass_rejected() {
    let mut dev = device();
    let enc = dev.create_command_encoder("e");
    dev.begin_compute_pass(enc).unwrap();
    assert!(dev.finish(enc).is_err());
}

#[test]
fn double_begin_pass_rejected() {
    let mut dev = device();
    let enc = dev.create_command_encoder("e");
    dev.begin_compute_pass(enc).unwrap();
    assert!(dev.begin_compute_pass(enc).is_err());
}

#[test]
fn command_buffer_single_submission() {
    let mut dev = device();
    let (pipeline, layout, b_in, b_out) = trivial_pipeline(&mut dev);
    let group = bind_buffers(&mut dev, "g", layout, &[b_in], &[b_out]).unwrap();
    let enc = dev.create_command_encoder("e");
    dev.begin_compute_pass(enc).unwrap();
    dev.set_pipeline(enc, pipeline).unwrap();
    dev.set_bind_group(enc, group).unwrap();
    dev.dispatch_workgroups(enc, 1, 1, 1).unwrap();
    dev.end_compute_pass(enc).unwrap();
    let cb = dev.finish(enc).unwrap();
    dev.submit(&[cb], &NullRunner).unwrap();
    // second submission of the same buffer must fail
    assert!(dev.submit(&[cb], &NullRunner).is_err());
}

#[test]
fn submit_rejects_destroyed_bound_buffer() {
    let mut dev = device();
    let (pipeline, layout, b_in, b_out) = trivial_pipeline(&mut dev);
    let group = bind_buffers(&mut dev, "g", layout, &[b_in], &[b_out]).unwrap();
    let enc = dev.create_command_encoder("e");
    dev.begin_compute_pass(enc).unwrap();
    dev.set_pipeline(enc, pipeline).unwrap();
    dev.set_bind_group(enc, group).unwrap();
    dev.dispatch_workgroups(enc, 1, 1, 1).unwrap();
    dev.end_compute_pass(enc).unwrap();
    let cb = dev.finish(enc).unwrap();
    dev.destroy_buffer(b_in).unwrap(); // destroy between finish and submit
    assert!(dev.submit(&[cb], &NullRunner).is_err());
}

fn trivial_pipeline(
    dev: &mut Device,
) -> (
    wdb::webgpu::ComputePipelineId,
    wdb::webgpu::BindGroupLayoutId,
    wdb::webgpu::BufferId,
    wdb::webgpu::BufferId,
) {
    let module = dev
        .create_shader_module(ShaderModuleDesc {
            label: "t".into(),
            kernel: "t".into(),
            inputs: vec![spec64()],
            outputs: vec![spec64()],
        })
        .unwrap();
    let layout = kernel_layout(dev, "t", 1, 1).unwrap();
    let pipeline = dev.create_compute_pipeline("t", module, layout).unwrap();
    let b_in = storage_buffer(dev, 256);
    let b_out = storage_buffer(dev, 256);
    (pipeline, layout, b_in, b_out)
}

// ----------------------------------------------------------- behaviors ----
#[test]
fn null_runner_dispatch_roundtrip() {
    let mut dev = device();
    let (pipeline, layout, b_in, b_out) = trivial_pipeline(&mut dev);
    run_kernel_dispatch(&mut dev, pipeline, layout, &[b_in], &[b_out], (1, 1, 1), &NullRunner)
        .unwrap();
    assert_eq!(dev.stats.dispatches_executed, 1);
    let bytes = dev.map_read(b_out).unwrap();
    assert!(bytes.iter().all(|&x| x == 0));
}

#[test]
fn batcher_flushes_at_batch_size() {
    let mut dev = device();
    let (pipeline, layout, b_in, b_out) = trivial_pipeline(&mut dev);
    let mut batcher = DispatchBatcher::new(4);
    for i in 0..10 {
        batcher
            .dispatch(&mut dev, pipeline, layout, &[b_in], &[b_out], (1, 1, 1), &NullRunner)
            .unwrap();
        let expected_submits = (i + 1) / 4;
        assert_eq!(dev.stats.submits, expected_submits as u64, "after {} dispatches", i + 1);
    }
    batcher.flush(&mut dev, &NullRunner).unwrap();
    assert_eq!(dev.stats.dispatches_executed, 10);
    assert_eq!(dev.stats.submits, 3); // 4 + 4 + final 2
}

#[test]
fn batching_reduces_per_dispatch_overhead_but_sync_negates_it() {
    // The paper's Table 16 null result: batching helps until a sync flushes
    // the queue every token anyway.
    let profile = ImplementationProfile::wgpu_vulkan_rtx5090();

    // Unbatched: 16 single-dispatch submits.
    let mut dev = Device::new(profile.clone());
    let (pipeline, layout, b_in, b_out) = trivial_pipeline(&mut dev);
    for _ in 0..16 {
        run_kernel_dispatch(&mut dev, pipeline, layout, &[b_in], &[b_out], (1, 1, 1), &NullRunner)
            .unwrap();
    }
    let unbatched = dev.clock.now_ns();

    // Batched: one submit of 16 dispatches.
    let mut dev = Device::new(profile);
    let (pipeline, layout, b_in, b_out) = trivial_pipeline(&mut dev);
    let mut batcher = DispatchBatcher::new(16);
    for _ in 0..16 {
        batcher
            .dispatch(&mut dev, pipeline, layout, &[b_in], &[b_out], (1, 1, 1), &NullRunner)
            .unwrap();
    }
    let batched = dev.clock.now_ns();
    assert!(
        batched < unbatched,
        "batching must reduce pure dispatch cost ({batched} vs {unbatched})"
    );
    // But with a sync after each *token* (one dispatch per token here), the
    // batch never fills and the benefit disappears:
    let mut dev = Device::new(ImplementationProfile::wgpu_vulkan_rtx5090());
    let (pipeline, layout, b_in, b_out) = trivial_pipeline(&mut dev);
    let mut batcher = DispatchBatcher::new(16);
    for _ in 0..16 {
        batcher
            .dispatch(&mut dev, pipeline, layout, &[b_in], &[b_out], (1, 1, 1), &NullRunner)
            .unwrap();
        batcher.flush(&mut dev, &NullRunner).unwrap(); // per-token sync flush
        dev.poll_wait();
    }
    let flushed = dev.clock.now_ns();
    assert!(flushed >= unbatched, "per-token sync must negate batching");
}

// -------------------------------------------------- session isolation ----
// Device-level invariants the multi-session serving engine depends on.

#[test]
fn destroying_one_sessions_buffers_keeps_other_bind_groups_valid() {
    // Two "sessions" each own buffers + a bind group over the SAME shared
    // pipeline. Destroying session A's buffers must not invalidate session
    // B's bind group — only A's own dispatches may fail.
    let mut dev = device();
    let (pipeline, layout, a_in, a_out) = trivial_pipeline(&mut dev);
    let b_in = storage_buffer(&mut dev, 256);
    let b_out = storage_buffer(&mut dev, 256);
    let group_a = bind_buffers(&mut dev, "session-a", layout, &[a_in], &[a_out]).unwrap();
    let group_b = bind_buffers(&mut dev, "session-b", layout, &[b_in], &[b_out]).unwrap();

    dev.destroy_buffer(a_in).unwrap();
    dev.destroy_buffer(a_out).unwrap();

    // Session B still dispatches cleanly.
    let enc = dev.create_command_encoder("b");
    dev.begin_compute_pass(enc).unwrap();
    dev.set_pipeline(enc, pipeline).unwrap();
    dev.set_bind_group(enc, group_b).unwrap();
    dev.dispatch_workgroups(enc, 1, 1, 1).unwrap();
    dev.end_compute_pass(enc).unwrap();
    let cb = dev.finish(enc).unwrap();
    dev.submit(&[cb], &NullRunner).unwrap();
    assert_eq!(dev.stats.dispatches_executed, 1);

    // Session A's group now fails at submit-time liveness validation.
    let enc = dev.create_command_encoder("a");
    dev.begin_compute_pass(enc).unwrap();
    dev.set_pipeline(enc, pipeline).unwrap();
    dev.set_bind_group(enc, group_a).unwrap();
    dev.dispatch_workgroups(enc, 1, 1, 1).unwrap();
    dev.end_compute_pass(enc).unwrap();
    let cb = dev.finish(enc).unwrap();
    assert!(dev.submit(&[cb], &NullRunner).is_err());
    // And B keeps working afterwards — the failure is contained.
    run_kernel_dispatch(&mut dev, pipeline, layout, &[b_in], &[b_out], (1, 1, 1), &NullRunner)
        .unwrap();
    assert_eq!(dev.stats.dispatches_executed, 2);
}

#[test]
fn retired_sessions_pooled_buffers_rebind_with_valid_usage() {
    // The executor's pool creates buffers with the full activation usage
    // set; a retired session's buffers must re-bind into a NEW session's
    // bind group and pass usage-flag validation unchanged.
    let mut dev = device();
    let (pipeline, layout, _, _) = trivial_pipeline(&mut dev);
    let pool_usage = BufferUsage::STORAGE
        | BufferUsage::COPY_DST
        | BufferUsage::COPY_SRC
        | BufferUsage::MAP_READ;
    let recycled_in = dev
        .create_buffer(BufferDesc { label: "pool-256".into(), size: 256, usage: pool_usage })
        .unwrap();
    let recycled_out = dev
        .create_buffer(BufferDesc { label: "pool-256".into(), size: 256, usage: pool_usage })
        .unwrap();

    // "Session 1" uses the buffers and retires (buffers return to pool).
    run_kernel_dispatch(
        &mut dev, pipeline, layout, &[recycled_in], &[recycled_out], (1, 1, 1), &NullRunner,
    )
    .unwrap();

    // "Session 2" re-acquires the same buffers: write, re-bind, dispatch,
    // map — every usage check must pass, zero validation errors.
    dev.write_buffer(recycled_in, 0, &[1u8; 64]).unwrap();
    let group2 =
        bind_buffers(&mut dev, "session-2", layout, &[recycled_in], &[recycled_out]).unwrap();
    let enc = dev.create_command_encoder("s2");
    dev.begin_compute_pass(enc).unwrap();
    dev.set_pipeline(enc, pipeline).unwrap();
    dev.set_bind_group(enc, group2).unwrap();
    dev.dispatch_workgroups(enc, 1, 1, 1).unwrap();
    dev.end_compute_pass(enc).unwrap();
    let cb = dev.finish(enc).unwrap();
    dev.submit(&[cb], &NullRunner).unwrap();
    let bytes = dev.map_read(recycled_out).unwrap();
    assert_eq!(bytes.len(), 256);
    assert_eq!(dev.stats.validation_errors, 0);
    assert_eq!(dev.stats.dispatches_executed, 2);
}

#[test]
fn coalesced_map_read_many_validates_each_buffer() {
    let mut dev = device();
    let ok_a = storage_buffer(&mut dev, 64);
    let ok_b = storage_buffer(&mut dev, 128);
    // Happy path: one sync, every buffer's bytes.
    let out = dev.map_read_many(&[ok_a, ok_b]).unwrap();
    assert_eq!(out.len(), 2);
    assert_eq!(out[0].len(), 64);
    assert_eq!(out[1].len(), 128);
    // Missing MAP_READ usage on ANY buffer fails the whole call.
    let no_map = dev
        .create_buffer(BufferDesc {
            label: "nm".into(),
            size: 16,
            usage: BufferUsage::STORAGE,
        })
        .unwrap();
    assert!(dev.map_read_many(&[ok_a, no_map]).is_err());
    // Destroyed buffers fail too.
    dev.destroy_buffer(ok_b).unwrap();
    assert!(dev.map_read_many(&[ok_a, ok_b]).is_err());
    // Empty set is a no-op (no sync cost).
    let t0 = dev.clock.now_ns();
    assert!(dev.map_read_many(&[]).unwrap().is_empty());
    assert_eq!(dev.clock.now_ns(), t0);
}

#[test]
fn error_paths_never_corrupt_device() {
    // After a storm of invalid calls the device still works.
    let mut dev = device();
    for _ in 0..50 {
        let _ = dev.create_buffer(BufferDesc {
            label: "bad".into(),
            size: 0,
            usage: BufferUsage::STORAGE,
        });
        let enc = dev.create_command_encoder("e");
        let _ = dev.dispatch_workgroups(enc, 1, 1, 1);
        let _ = dev.finish(enc);
    }
    assert!(dev.stats.validation_errors >= 50);
    let (pipeline, layout, b_in, b_out) = trivial_pipeline(&mut dev);
    run_kernel_dispatch(&mut dev, pipeline, layout, &[b_in], &[b_out], (1, 1, 1), &NullRunner)
        .unwrap();
    assert_eq!(dev.stats.dispatches_executed, 1);
}
